"""Test configuration.

x64 is enabled because the Ozaki emulation targets FP64-equivalent accuracy and the
tests compare against true float64 oracles (this is a CPU container; TPU is the
compile target).  Device count stays at 1 — only launch/dryrun.py (run as a script)
forces the 512-device host platform.
"""

import jax

jax.config.update("jax_enable_x64", True)
