"""Sharding-rule unit tests (no multi-device requirement: specs only)."""

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.distributed import sharding
from repro.models.transformer import Model


def _fake_mesh(data=16, model=16, pod=None):
    """AbstractMesh stands in for the production mesh (no devices needed)."""
    from jax.sharding import AbstractMesh
    if pod:
        sizes, names = (pod, data, model), ("pod", "data", "model")
    else:
        sizes, names = (data, model), ("data", "model")
    try:
        return AbstractMesh(sizes, names)            # jax >= 0.5 signature
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))  # 0.4.x: shape_tuple


def _specs_for(arch, layout="tp", mesh=None):
    cfg = registry.get_config(arch, smoke=False)
    mesh = mesh or _fake_mesh()
    model = Model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return cfg, params, sharding.param_specs(cfg, mesh, params, layout)


def _flat(params, specs):
    out = {}
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = (leaf, spec)
    return out


def test_every_sharded_dim_divides(monkeypatch):
    mesh = _fake_mesh()
    for arch in registry.list_archs():
        cfg, params, specs = _specs_for(arch, mesh=mesh)
        for key, (leaf, spec) in _flat(params, specs).items():
            for d, ax in enumerate(spec):
                if ax is None:
                    continue
                size = 1
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    size *= dict(zip(mesh.axis_names, mesh.axis_sizes))[a]
                assert leaf.shape[d] % size == 0, (arch, key, spec, leaf.shape)


def test_tp_layout_uses_model_axis():
    _, params, specs = _specs_for("yi-6b", layout="tp")
    flat = _flat(params, specs)
    mlp_spec = flat["stack/b0/mlp/wi_up/w"][1]
    assert "model" in jax.tree_util.tree_leaves(
        [a for a in mlp_spec if a is not None]) or "model" in str(mlp_spec)


def test_fsdp_layout_has_no_model_tp():
    """fsdp layout: weights sharded over all axes but never TP on 'model' alone."""
    _, params, specs = _specs_for("yi-6b", layout="fsdp")
    for key, (leaf, spec) in _flat(params, specs).items():
        for ax in spec:
            if ax == "model":
                raise AssertionError(f"{key} still TP-sharded: {spec}")


def test_fsdp_layout_shards_big_weights():
    _, params, specs = _specs_for("yi-6b", layout="fsdp")
    flat = _flat(params, specs)
    leaf, spec = flat["embed/table"]
    assert any(a is not None for a in spec), spec


def test_moe_experts_on_model_axis():
    _, params, specs = _specs_for("deepseek-moe-16b")
    flat = _flat(params, specs)
    leaf, spec = flat["stack/b0/mlp/experts/wi_up"]
    assert spec[1] == "model"       # leading periods axis, then experts


def test_whisper_vocab_not_sharded():
    """51865 is not divisible by 16: vocab sharding must be dropped."""
    _, params, specs = _specs_for("whisper-medium")
    flat = _flat(params, specs)
    leaf, spec = flat["embed/table"]
    assert spec[0] is None
    assert leaf.shape[0] == 51865


def test_logical_rules_head_fallback():
    mesh = _fake_mesh()
    r_ok = sharding.logical_rules(registry.get_config("yi-6b"), mesh)
    assert r_ok["heads"] == "model" and r_ok["aseq"] is None
    r_fb = sharding.logical_rules(registry.get_config("minitron-4b"), mesh)
    assert r_fb["heads"] is None and r_fb["aseq"] == "model"  # context-parallel


def test_cache_specs_decode():
    cfg = registry.get_config("yi-6b")
    mesh = _fake_mesh()
    model = Model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(128, 1024))
    specs = sharding.cache_specs(cfg, mesh, cache, batch_size=128)
    flat = _flat(cache, specs)
    leaf, spec = flat["stack/b0/kv/k"]
    assert spec[1] == "data"        # batch on data (after stacked periods axis)
    # kv=4 not divisible by 16 -> head_dim sharded
    assert spec[4] == "model"
