"""Structured-grid dwarf: weighted-Jacobi on the 7-point Dirichlet Laplacian.

The operator rides the dispatch-routed stencil kernel; the solver is
validated against the spectral direct solver (odd extension of the PR-4
periodic FFT solve) — the two dwarfs must agree on the same discrete problem.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch
from repro.hpc import jacobi, poisson
from repro.kernels import ref

RNG = np.random.default_rng(11)


def test_operator_matches_f64_stencil_reference():
    u = jnp.asarray(RNG.standard_normal((8, 7, 9)))
    got = np.asarray(jacobi.apply_dirichlet_laplacian(u))
    want = np.asarray(ref.stencil7_f64(u, jacobi.laplacian_coeffs()))
    scale = 7.0 * np.max(np.abs(np.asarray(u))) * 2.0
    assert np.max(np.abs(got - want)) <= 8 * 2.0 ** -53 * scale


def test_manufactured_solution_recovered():
    """f = Δ_h u*  ->  Jacobi recovers u* to the stopping tolerance."""
    u_exact = jnp.asarray(RNG.standard_normal((6, 6, 6)))
    f = jacobi.apply_dirichlet_laplacian(u_exact)
    res = jacobi.jacobi_solve(f, tol=1e-9, maxiter=500, check_every=4)
    assert res.converged
    assert res.iters < 500
    np.testing.assert_allclose(np.asarray(res.u), np.asarray(u_exact),
                               rtol=0, atol=1e-8)


def test_jacobi_matches_spectral_direct_solver():
    """Cross-dwarf validation: relaxation (stencil kernel) and the spectral
    direct solve (emulated FFT, odd extension) agree on the same FD problem."""
    f = jnp.asarray(RNG.standard_normal((6, 5, 7)))
    res = jacobi.jacobi_solve(f, tol=1e-10, maxiter=1500, check_every=8)
    u_spec = poisson.poisson_solve_dirichlet(f)
    assert res.converged
    np.testing.assert_allclose(np.asarray(res.u), np.asarray(u_spec),
                               rtol=0, atol=1e-8)


def test_weighted_jacobi_omega_converges_monotonically():
    """ω = 2/3 (the multigrid smoother weighting) still converges, and the
    recorded compensated residual history decreases."""
    u_exact = jnp.asarray(RNG.standard_normal((5, 5, 5)))
    f = jacobi.apply_dirichlet_laplacian(u_exact)
    res = jacobi.jacobi_solve(f, omega=2.0 / 3.0, tol=1e-6, maxiter=1500,
                              check_every=10)
    assert res.converged
    assert all(b <= a * (1 + 1e-12)
               for a, b in zip(res.history, res.history[1:]))


def test_anisotropic_spacings():
    u_exact = jnp.asarray(RNG.standard_normal((6, 6, 6)))
    spacings = (0.5, 1.0, 0.25)
    f = jacobi.apply_dirichlet_laplacian(u_exact, spacings=spacings)
    res = jacobi.jacobi_solve(f, spacings=spacings, tol=1e-9, maxiter=1000,
                              check_every=8)
    assert res.converged
    np.testing.assert_allclose(np.asarray(res.u), np.asarray(u_exact),
                               rtol=0, atol=1e-7)


def test_jacobi_routes_bit_identical():
    """The whole relaxation is on the dispatch seam: forcing the xla route
    reproduces the ambient (auto) solve bit-for-bit on this backend, and an
    explicit mode_scope override is honoured."""
    f = jnp.asarray(RNG.standard_normal((5, 5, 5)))
    res_auto = jacobi.jacobi_solve(f, tol=1e-6, maxiter=400)
    res_xla = jacobi.jacobi_solve(f, tol=1e-6, maxiter=400, mode="xla")
    import jax
    if jax.default_backend() != "tpu":
        np.testing.assert_array_equal(np.asarray(res_auto.u),
                                      np.asarray(res_xla.u))
    with dispatch.mode_scope("xla"):
        res_scoped = jacobi.jacobi_solve(f, tol=1e-6, maxiter=400)
    np.testing.assert_array_equal(np.asarray(res_scoped.u),
                                  np.asarray(res_xla.u))


def test_rejects_non_3d_grids():
    with pytest.raises(ValueError):
        jacobi.jacobi_solve(jnp.zeros((4, 4)))
