"""Per-architecture smoke tests (assignment requirement f).

Each assigned architecture instantiates its REDUCED config and runs one forward /
train step on CPU, asserting output shapes and no NaNs.  Representative archs also
get a decode-vs-forward consistency check (the cache correctness oracle: decoding
token-by-token must reproduce the teacher-forced forward logits).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import SHAPES_BY_NAME
from repro.models.transformer import Model

# Full per-arch forward/train/decode sweeps: minutes of CPU compile time.
pytestmark = pytest.mark.slow

ARCHS = registry.list_archs()
TRAIN = SHAPES_BY_NAME["train_4k"]


def _setup(arch, **overrides):
    cfg = registry.get_config(arch, smoke=True, **overrides)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, model, params = _setup(arch)
    batch = registry.concrete_batch(cfg, TRAIN, batch=2, seq=16)
    logits, aux = model.apply(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    from repro.train.loop import make_train_step
    from repro.optim.adamw import adamw_init

    cfg, model, params = _setup(arch)
    opt_state = adamw_init(params)
    step_fn = make_train_step(model)
    batch = registry.concrete_batch(cfg, TRAIN, batch=2, seq=16)
    params2, opt_state2, metrics = step_fn(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # parameters actually moved
    moved = jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, params2)
    assert any(jax.tree.leaves(moved))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg, model, params = _setup(arch)
    cache = model.init_cache(batch=2, seq_len=24)
    logits, cache2 = model.decode_step(
        params, cache, jnp.zeros((2, 1), jnp.int32), jnp.asarray(3, jnp.int32))
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", [
    "yi-6b", "gemma3-4b", "jamba-1.5-large-398b", "xlstm-350m",
    "deepseek-moe-16b",
])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the teacher-forced forward logits.

    MoE archs use a no-drop capacity factor here: capacity-based token dropping
    is a train-time batch effect that single-token decode (correctly) never
    reproduces — the standard train/serve MoE divergence.

    The jamba/deepseek xfails that shipped with the seed were root-caused to
    the KV cache being hard-coded bfloat16 while forward ran in the compute
    dtype: the quantisation noise (~7e-3 in the scores) was amplified by MoE
    top-k routing flips at near-tied expert boundaries into 0.1–0.35 logit
    errors.  With the cache in compute dtype (attention.cache_init), decode
    is bit-identical to forward for every arch here.
    """
    over = {}
    base = registry.get_config(arch, smoke=True)
    if base.moe is not None:
        over["moe"] = dataclasses.replace(base.moe, capacity_factor=16.0)
    cfg, model, params = _setup(arch, compute_dtype="float32", **over)
    if cfg.frontend == "vision":
        pytest.skip("decode over stub embeds not defined")
    S = 12
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, S)), jnp.int32)
    full_logits, _ = model.apply(params, {"tokens": tokens})

    cache = model.init_cache(batch=2, seq_len=S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, tokens[:, t:t + 1],
                                      jnp.asarray(t, jnp.int32))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(full_logits),
                               rtol=2e-2, atol=2e-2)


def test_sliding_window_masks_differ_from_global():
    """gemma3 local layers must not see past the window."""
    cfg, model, params = _setup("gemma3-4b", compute_dtype="float32")
    rng = np.random.default_rng(1)
    S = 24
    t1 = rng.integers(0, cfg.vocab_size, (1, S))
    t2 = t1.copy()
    t2[0, 0] = (t2[0, 0] + 7) % cfg.vocab_size  # perturb a distant-past token
    l1, _ = model.apply(params, {"tokens": jnp.asarray(t1, jnp.int32)})
    l2, _ = model.apply(params, {"tokens": jnp.asarray(t2, jnp.int32)})
    # late positions still differ (global layers see everything)...
    assert float(jnp.max(jnp.abs(l1[0, -1] - l2[0, -1]))) > 0
    # ...but causality holds: positions before the perturbation are identical
    np.testing.assert_array_equal(np.asarray(l1[0, :0]), np.asarray(l2[0, :0]))


@pytest.mark.parametrize("arch", ["yi-6b", "jamba-1.5-large-398b", "xlstm-350m"])
def test_causality(arch):
    """Changing token t must not affect logits at positions < t."""
    cfg, model, params = _setup(arch, compute_dtype="float32")
    rng = np.random.default_rng(2)
    S = 10
    t1 = rng.integers(0, cfg.vocab_size, (1, S))
    t2 = t1.copy()
    t2[0, 6] = (t2[0, 6] + 3) % cfg.vocab_size
    l1, _ = model.apply(params, {"tokens": jnp.asarray(t1, jnp.int32)})
    l2, _ = model.apply(params, {"tokens": jnp.asarray(t2, jnp.int32)})
    np.testing.assert_allclose(np.asarray(l1[0, :6]), np.asarray(l2[0, :6]),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.max(jnp.abs(l1[0, 6:] - l2[0, 6:]))) > 0


def test_moe_router_balanced_dispatch():
    """MoE: every token gets routed; aux loss near 1.0 for uniform random."""
    cfg, model, params = _setup("deepseek-moe-16b", compute_dtype="float32")
    batch = registry.concrete_batch(cfg, TRAIN, batch=4, seq=16)
    _, aux = model.apply(params, batch)
    assert 0.5 < float(aux) < 4.0  # near num_experts * E[me*ce] ~= 1 when balanced


def test_full_configs_param_counts():
    """Full configs match the advertised sizes (±15%)."""
    expected = {
        "qwen2-vl-72b": 72e9, "yi-6b": 6e9, "gemma-7b": 8.5e9,
        "gemma3-4b": 4e9, "jamba-1.5-large-398b": 398e9,
        "deepseek-moe-16b": 16e9, "xlstm-350m": 0.35e9,
        "llama4-scout-17b-a16e": 109e9,
    }
    for arch, want in expected.items():
        got = registry.get_config(arch).param_count()
        assert 0.8 * want < got < 1.25 * want, (arch, got, want)
    # MoE active counts
    assert abs(registry.get_config("llama4-scout-17b-a16e").active_param_count()
               - 17e9) < 3e9
    assert registry.get_config("jamba-1.5-large-398b").active_param_count() < 120e9


def test_mrope_positions_affect_output():
    cfg, model, params = _setup("qwen2-vl-72b", compute_dtype="float32")
    rng = np.random.default_rng(3)
    S = 8
    emb = jnp.asarray(rng.standard_normal((1, S, cfg.d_model)), jnp.float32)
    p1 = jnp.asarray(np.broadcast_to(np.arange(S), (1, 3, S)).copy(), jnp.int32)
    p2 = p1.at[0, 1].set(jnp.arange(S) * 3)  # different h-stream positions
    l1, _ = model.apply(params, {"embeds": emb, "positions": p1})
    l2, _ = model.apply(params, {"embeds": emb, "positions": p2})
    assert float(jnp.max(jnp.abs(l1 - l2))) > 0


def test_runnable_cells_enumeration():
    cells = registry.runnable_cells()
    assert len(cells) == 33  # 40 - 7 long_500k skips
    skipped = [(a, s.name) for a in registry.list_archs()
               for s in registry.SHAPES
               if not registry.cell_is_runnable(a, s)[0]]
    assert len(skipped) == 7
    assert all(s == "long_500k" for _, s in skipped)
