"""§7.1(a) integration: CG with Ozaki-II SpMV + compensated dots."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.hpc import spmv_formats
from repro.hpc.cg import cg_solve, cg_solve_bell


def test_blocked_ell_roundtrip():
    dense = spmv_formats.laplacian_1d(32)
    val, col = spmv_formats.to_blocked_ell(dense, bw=4)
    # reconstruct
    back = np.zeros_like(dense)
    for i in range(32):
        for s in range(4):
            back[i, col[i, s]] += val[i, s]
    np.testing.assert_array_equal(back, dense)
    assert spmv_formats.padding_ratio(val) == pytest.approx(128 / 94, rel=0.01)


def test_bell_rejects_overfull_rows():
    dense = np.ones((4, 8))
    with pytest.raises(ValueError):
        spmv_formats.to_blocked_ell(dense, bw=4)


def test_cg_native_converges():
    dense = spmv_formats.laplacian_2d(8, 8)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(64))
    res = cg_solve(lambda x: jnp.asarray(dense) @ x, b, tol=1e-10)
    assert res.converged
    x = np.asarray(res.x)
    np.testing.assert_allclose(dense @ x, np.asarray(b), atol=1e-8)


@pytest.mark.slow
def test_cg_with_ozaki_spmv_matches_native():
    """The paper's claim: the emulated path changes nothing for the solver.

    slow: the interpret-mode Blocked-ELL SpMV pays a multi-minute XLA compile
    on CPU (the gather-heavy kernel graph); the compiled TPU path does not.
    """
    dense = spmv_formats.laplacian_2d(8, 8)
    val, col = spmv_formats.to_blocked_ell(dense, bw=8)
    rng = np.random.default_rng(1)
    b = jnp.asarray(rng.standard_normal(64))
    ref = cg_solve(lambda x: jnp.asarray(dense) @ x, b, tol=1e-10)
    emu = cg_solve_bell(jnp.asarray(val), jnp.asarray(col), b, tol=1e-10)
    assert emu.converged
    assert abs(emu.iters - ref.iters) <= 1   # convergence history preserved
    np.testing.assert_allclose(np.asarray(emu.x), np.asarray(ref.x),
                               rtol=0, atol=1e-8)


def test_cg_residual_history_monotonic_tail():
    dense = spmv_formats.laplacian_1d(48)
    b = jnp.asarray(np.random.default_rng(2).standard_normal(48))
    res = cg_solve(lambda x: jnp.asarray(dense) @ x, b, tol=1e-10, maxiter=200)
    assert res.converged
    assert res.history[-1] < 1e-10
