"""§7.1(a) integration: CG with Ozaki-II SpMV + compensated dots."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.hpc import spmv_formats
from repro.hpc.cg import cg_solve, cg_solve_bell


def test_blocked_ell_roundtrip():
    dense = spmv_formats.laplacian_1d(32)
    val, col = spmv_formats.to_blocked_ell(dense, bw=4)
    # reconstruct
    back = np.zeros_like(dense)
    for i in range(32):
        for s in range(4):
            back[i, col[i, s]] += val[i, s]
    np.testing.assert_array_equal(back, dense)
    assert spmv_formats.padding_ratio(val) == pytest.approx(128 / 94, rel=0.01)


def test_bell_rejects_overfull_rows():
    dense = np.ones((4, 8))
    with pytest.raises(ValueError):
        spmv_formats.to_blocked_ell(dense, bw=4)


def test_cg_native_converges():
    dense = spmv_formats.laplacian_2d(8, 8)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(64))
    res = cg_solve(lambda x: jnp.asarray(dense) @ x, b, tol=1e-10)
    assert res.converged
    x = np.asarray(res.x)
    np.testing.assert_allclose(dense @ x, np.asarray(b), atol=1e-8)


def test_cg_with_ozaki_spmv_matches_native():
    """The paper's claim: the emulated path changes nothing for the solver.

    mode="xla" pins the matvec to the bit-identical jnp reference route
    (route-independent result; the interpret-mode Pallas path, with its
    multi-minute XLA compile at the default plan, is covered by the slow
    parity test in test_kernels.py — pinning keeps the CI
    REPRO_DISPATCH=pallas leg off that compile).
    """
    dense = spmv_formats.laplacian_2d(8, 8)
    val, col = spmv_formats.to_blocked_ell(dense, bw=8)
    rng = np.random.default_rng(1)
    b = jnp.asarray(rng.standard_normal(64))
    ref = cg_solve(lambda x: jnp.asarray(dense) @ x, b, tol=1e-10)
    emu = cg_solve_bell(jnp.asarray(val), jnp.asarray(col), b, tol=1e-10,
                        mode="xla")
    assert emu.converged
    assert abs(emu.iters - ref.iters) <= 1   # convergence history preserved
    np.testing.assert_allclose(np.asarray(emu.x), np.asarray(ref.x),
                               rtol=0, atol=1e-8)


def test_cg_residual_history_monotonic_tail():
    dense = spmv_formats.laplacian_1d(48)
    b = jnp.asarray(np.random.default_rng(2).standard_normal(48))
    res = cg_solve(lambda x: jnp.asarray(dense) @ x, b, tol=1e-10, maxiter=200)
    assert res.converged
    assert res.history[-1] < 1e-10


def test_cg_records_plain_and_compensated_histories():
    """Both residual histories cover every iterate and measure the same r."""
    dense = spmv_formats.laplacian_1d(32)
    b = jnp.asarray(np.random.default_rng(3).standard_normal(32))
    res = cg_solve(lambda x: jnp.asarray(dense) @ x, b, tol=1e-10)
    assert len(res.history_plain) == len(res.history) == res.iters + 1
    # In f64 the two agree to rounding; they are distinct computations.
    np.testing.assert_allclose(res.history_plain, res.history, rtol=1e-10)
    # Opt-out drops the shadow reduction entirely.
    quiet = cg_solve(lambda x: jnp.asarray(dense) @ x, b, tol=1e-10,
                     record_plain=False)
    assert quiet.history_plain == [] and quiet.converged


def test_cg_compensated_vs_plain_delta_observable_f32():
    """In f32 the plain-dot residual history drifts from the compensated one
    by far more than f64 roundoff — the §7.1(a) delta, made visible."""
    dense = jnp.asarray(spmv_formats.laplacian_2d(8, 8), jnp.float32)
    b = jnp.asarray(np.random.default_rng(4).standard_normal(64), jnp.float32)
    res = cg_solve(lambda x: dense @ x, b, tol=1e-6, maxiter=80)
    deltas = [abs(p - c) / max(c, 1e-30)
              for p, c in zip(res.history_plain, res.history)]
    # same quantity ...
    assert max(deltas) < 1e-2
    # ... but the plain-f32 reductions are visibly off the compensated ones
    # (the compensated dot carries ~2^-48; plain f32 only ~2^-24·n).
    assert max(deltas) > 2.0 ** -24


def test_cg_iteration_counts_unchanged_by_blocked_eft():
    """The blocked-EFT swap must not move CG's trajectory: driving the
    recurrence with the element-wise scan reference (the pre-blocking
    implementation) yields the same iteration count and the same residual
    history to a few ulps."""
    from repro.core import compensated

    dense = jnp.asarray(spmv_formats.laplacian_2d(8, 8))
    b = jnp.asarray(np.random.default_rng(5).standard_normal(64))
    blocked = cg_solve(lambda x: dense @ x, b, tol=1e-10, maxiter=200,
                       record_plain=False)
    scan = cg_solve(lambda x: dense @ x, b, tol=1e-10, maxiter=200,
                    dot=compensated.compensated_dot_scan,
                    record_plain=False)
    assert blocked.converged and scan.converged
    assert blocked.iters == scan.iters
    np.testing.assert_allclose(blocked.history, scan.history, rtol=1e-12)
