"""Distributed machinery tests on a multi-device host platform.

These run in a SUBPROCESS with --xla_force_host_platform_device_count=8 so the
main test process keeps its single-device view (assignment requirement).
"""

import os
import subprocess
import sys
import textwrap

import pytest

# Each test spawns a fresh 8-device subprocess (recompiles everything).
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_train_step_runs_on_8_devices():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import registry
        from repro.distributed import sharding
        from repro.models.transformer import Model
        from repro.optim import adamw
        from repro.train.loop import make_train_step

        cfg = registry.get_config("yi-6b", smoke=True)
        mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
        sharding.install_annotations(cfg, mesh)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        ps = sharding.param_shardings(cfg, mesh, params)
        params = jax.device_put(params, ps)
        opt = adamw.adamw_init(params)
        os_ = sharding.opt_state_shardings(cfg, mesh, opt, params)
        opt = jax.device_put(opt, os_)
        batch = registry.concrete_batch(
            cfg, registry.SHAPES_BY_NAME["train_4k"], batch=8, seq=16)
        bs = sharding.batch_shardings(
            cfg, registry.SHAPES_BY_NAME["train_4k"], mesh, batch)
        batch = jax.device_put(batch, bs)
        step = jax.jit(make_train_step(model),
                       in_shardings=(ps, os_, bs), out_shardings=(ps, os_, None))
        p2, o2, m = step(params, opt, batch)
        assert bool(jnp.isfinite(m["loss"]))
        print("LOSS", float(m["loss"]))
    """)
    assert "LOSS" in out


def test_sharded_matches_single_device():
    """Same init + batch: 8-device sharded step == single-device step."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import registry
        from repro.distributed import sharding
        from repro.models.transformer import Model
        from repro.optim import adamw
        from repro.train.loop import make_train_step

        cfg = registry.get_config("gemma3-4b", smoke=True,
                                  compute_dtype="float32")
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw.adamw_init(params)
        batch = registry.concrete_batch(
            cfg, registry.SHAPES_BY_NAME["train_4k"], batch=8, seq=16)
        step1 = jax.jit(make_train_step(model))
        _, _, m1 = step1(params, opt, batch)

        mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
        sharding.install_annotations(cfg, mesh)
        ps = sharding.param_shardings(cfg, mesh, params)
        os_ = sharding.opt_state_shardings(cfg, mesh, opt, params)
        bs = sharding.batch_shardings(
            cfg, registry.SHAPES_BY_NAME["train_4k"], mesh, batch)
        stepN = jax.jit(make_train_step(model),
                        in_shardings=(ps, os_, bs),
                        out_shardings=(ps, os_, None))
        _, _, mN = stepN(jax.device_put(params, ps), jax.device_put(opt, os_),
                         jax.device_put(batch, bs))
        d = abs(float(m1["loss"]) - float(mN["loss"]))
        print("DELTA", d)
        assert d < 5e-4, (float(m1["loss"]), float(mN["loss"]))
    """)
    assert "DELTA" in out


def test_pipeline_parallel_1f1b():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.distributed import pipeline_parallel as pp

        S, M, mb, d = 4, 8, 2, 16
        mesh = Mesh(np.asarray(jax.devices()[:S]), ("pipe",))
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (S, d, d)) * 0.3

        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"])

        xs = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
        got = pp.pipeline_forward(stage_fn, {"w": ws}, xs, mesh)
        # sequential reference
        want = xs
        for s in range(S):
            want = jax.vmap(lambda x: stage_fn({"w": ws[s]}, x))(want)
        err = float(jnp.max(jnp.abs(got - want)))
        print("ERR", err)
        assert err < 1e-5
        assert abs(pp.bubble_fraction(M, S) - 3/11) < 1e-9
    """, devices=4)
    assert "ERR" in out


def test_elastic_remesh_preserves_params():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import registry
        from repro.distributed import elastic, sharding
        from repro.models.transformer import Model
        from repro.optim import adamw

        cfg = registry.get_config("yi-6b", smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw.adamw_init(params)
        # start on 8 devices (4 data x 2 model), lose half -> 4 devices
        mesh8, p8, o8 = elastic.elastic_remesh(cfg, params, opt,
                                               jax.devices()[:8], 2)
        mesh4, p4, o4 = elastic.elastic_remesh(cfg, p8, o8,
                                               jax.devices()[:4], 2)
        ok = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), params, p4)
        assert all(jax.tree.leaves(ok))
        print("REMESH OK", mesh8.shape, "->", mesh4.shape)
    """)
    assert "REMESH OK" in out


def test_pipeline_parallel_stage_params_helper():
    from repro.distributed import pipeline_parallel as pp
    assert pp.bubble_fraction(8, 4) == pytest.approx(3 / 11)
    assert pp.bubble_fraction(1, 1) == 0.0
