"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles.

Every fused Pallas kernel (interpret=True on this CPU container; Mosaic on TPU) is
checked two ways:
  1. accuracy vs the float64 oracle (§2.5 error band),
  2. BIT-EXACT equality of the f64 output mode against the unfused XLA
     implementation (repro.core.ozaki2) — this pins every integer step.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ozaki2
from repro.kernels import ops, ref

U64 = 2.0 ** -53
RNG = np.random.default_rng(123)


def _gemm_err(c, a, b):
    denom = np.abs(np.asarray(a)) @ np.abs(np.asarray(b)) + 1e-300
    return np.max(np.abs(np.asarray(c) - np.asarray(ref.gemm_f64(a, b))) / denom)


# ---------------------------------------------------------------------------
# GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mkn,blocks", [
    ((16, 32, 16), (16, 16, 32)),
    ((40, 70, 24), (16, 8, 32)),       # ragged: padding path
    ((128, 256, 64), (64, 32, 128)),   # multi-step K accumulation
    ((8, 8, 8), (8, 8, 8)),            # single block
])
@pytest.mark.parametrize("out_rep", ["f64", "digits"])
def test_gemm_accuracy_sweep(mkn, blocks, out_rep):
    m, k, n = mkn
    bm, bn, bk = blocks
    a = jnp.asarray(RNG.standard_normal((m, k)))
    b = jnp.asarray(RNG.standard_normal((k, n)))
    c = ops.ozaki_gemm(a, b, out_rep=out_rep, bm=bm, bn=bn, bk=bk)
    assert _gemm_err(c, a, b) <= 16 * U64


def test_gemm_ds_mode_precision():
    a = jnp.asarray(RNG.standard_normal((32, 64)))
    b = jnp.asarray(RNG.standard_normal((64, 32)))
    c = ops.ozaki_gemm(a, b, out_rep="ds", bm=16, bn=16, bk=32)
    err = _gemm_err(c, a, b)
    assert err <= 2.0 ** -44  # double-single carries ~45-48 bits
    assert err > 2.0 ** -60   # ...but is not full f64 (sanity on the mode split)


def test_gemm_kernel_bitexact_vs_xla_ozaki2():
    a = jnp.asarray(RNG.standard_normal((24, 48)))
    b = jnp.asarray(RNG.standard_normal((48, 16)))
    plan = ozaki2.make_plan(48)
    c_kernel = ops.ozaki_gemm(a, b, plan=plan, out_rep="f64", bm=8, bn=8, bk=16)
    c_xla = ozaki2.emulated_matmul(a, b, plan)
    np.testing.assert_array_equal(np.asarray(c_kernel), np.asarray(c_xla))


def test_gemm_f32_inputs():
    a = jnp.asarray(RNG.standard_normal((16, 32)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((32, 16)), jnp.float32)
    plan = ozaki2.make_plan(32, payload_bits=24)
    c = ops.ozaki_gemm(a, b, plan=plan, bm=16, bn=16, bk=32)
    want = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    denom = np.abs(np.asarray(a, np.float64)) @ np.abs(np.asarray(b, np.float64))
    assert np.max(np.abs(np.asarray(c) - want) / denom) <= 2.0 ** -22


# ---------------------------------------------------------------------------
# Batched GEMV (Algorithm 1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mnb", [(64, 96, 8), (33, 70, 2), (128, 64, 4)])
@pytest.mark.parametrize("out_rep", ["f64", "digits"])
def test_gemv_accuracy_sweep(mnb, out_rep):
    m, n, bsz = mnb
    a = jnp.asarray(RNG.standard_normal((m, n)))
    x = jnp.asarray(RNG.standard_normal((n, bsz)))
    y = ops.ozaki_gemv(a, x, out_rep=out_rep, bm=16, bk=32)
    denom = np.abs(np.asarray(a)) @ np.abs(np.asarray(x)) + 1e-300
    err = np.max(np.abs(np.asarray(y) - np.asarray(ref.gemv_f64(a, x))) / denom)
    assert err <= 16 * U64


def test_gemv_matches_gemm_kernel():
    a = jnp.asarray(RNG.standard_normal((32, 64)))
    x = jnp.asarray(RNG.standard_normal((64, 8)))
    plan = ozaki2.make_plan(64)
    y1 = ops.ozaki_gemv(a, x, plan=plan, bm=16, bk=32)
    y2 = ops.ozaki_gemm(a, x, plan=plan, bm=16, bn=8, bk=32)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


# ---------------------------------------------------------------------------
# 7-point stencil (Algorithm 2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,bz", [
    ((12, 10, 20), 4),
    ((8, 8, 8), 8),      # single slab
    ((6, 7, 13), 4),     # ragged z: padding path
])
@pytest.mark.parametrize("out_rep", ["f64", "digits"])
def test_stencil_accuracy_sweep(shape, bz, out_rep):
    u = jnp.asarray(RNG.standard_normal(shape))
    c = jnp.asarray(np.array([6.0, -1.0, -1.0, -1.0, -1.0, -1.0, -1.0]))
    v = ops.ozaki_stencil7(u, c, out_rep=out_rep, bz=bz)
    want = np.asarray(ref.stencil7_f64(u, c))
    scale = 7 * np.max(np.abs(np.asarray(u))) * np.max(np.abs(np.asarray(c)))
    assert np.max(np.abs(np.asarray(v) - want)) <= 8 * U64 * scale
    assert v.shape == shape


def test_stencil_boundary_zero_halo():
    """Points on the global boundary must see a zero halo, not wraparound."""
    u = jnp.asarray(np.ones((4, 4, 8)))
    c = jnp.asarray(np.array([0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0]))  # pure -z shift
    v = np.asarray(ops.ozaki_stencil7(u, c, bz=4))
    assert np.all(v[:, :, 0] == 0.0)   # first plane has no -z neighbour
    assert np.all(v[:, :, 1:] == 1.0)


def test_stencil_anisotropic_coeffs():
    u = jnp.asarray(RNG.standard_normal((8, 8, 8)))
    c = jnp.asarray(RNG.standard_normal(7))
    v = np.asarray(ops.ozaki_stencil7(u, c, bz=4))
    want = np.asarray(ref.stencil7_f64(u, c))
    scale = float(7 * jnp.max(jnp.abs(u)) * jnp.max(jnp.abs(c)))
    assert np.max(np.abs(v - want)) <= 8 * U64 * scale


# ---------------------------------------------------------------------------
# Blocked-ELL SpMV (Algorithm 3)
# ---------------------------------------------------------------------------

def _random_bell(m, n, bw, zero_frac=0.2):
    col = RNG.integers(0, n, (m, bw)).astype(np.int32)
    val = RNG.standard_normal((m, bw))
    val[RNG.random((m, bw)) < zero_frac] = 0.0  # structural zeros (padding)
    return jnp.asarray(val), jnp.asarray(col), jnp.asarray(RNG.standard_normal(n))


@pytest.mark.parametrize("mnbw", [(50, 64, 8), (128, 32, 16), (17, 100, 4)])
@pytest.mark.parametrize("out_rep", ["f64", "digits"])
def test_spmv_accuracy_sweep(mnbw, out_rep):
    # mode="xla" pins the arithmetic to the bit-identical reference route:
    # accuracy is route-independent, and under the CI REPRO_DISPATCH=pallas
    # leg the default-plan interpreter would pay minutes of XLA-CPU compile.
    m, n, bw = mnbw
    val, col, x = _random_bell(m, n, bw)
    y = ops.ozaki_spmv_bell(val, col, x, out_rep=out_rep, br=16, mode="xla")
    want = np.asarray(ref.spmv_bell_f64(val, col, x))
    denom = (np.abs(np.asarray(val)).sum(-1) * np.max(np.abs(np.asarray(x)))
             + 1e-300)
    assert np.max(np.abs(np.asarray(y) - want) / denom) <= 16 * U64


def test_spmv_laplacian_1d():
    """A real PDE matrix: 1-D Laplacian in ELL form, y = A x exact vs dense."""
    n = 96
    dense = (np.diag(2.0 * np.ones(n)) - np.diag(np.ones(n - 1), 1)
             - np.diag(np.ones(n - 1), -1))
    col = np.zeros((n, 4), np.int32)
    val = np.zeros((n, 4))
    for i in range(n):
        nz = [(j, dense[i, j]) for j in range(n) if dense[i, j] != 0]
        for s, (j, v) in enumerate(nz):
            col[i, s], val[i, s] = j, v
    x = RNG.standard_normal(n)
    y = np.asarray(ops.ozaki_spmv_bell(jnp.asarray(val), jnp.asarray(col),
                                       jnp.asarray(x), br=32, mode="xla"))
    np.testing.assert_allclose(y, dense @ x, rtol=0, atol=4 * U64 * 4 * np.abs(x).max())


@pytest.mark.slow  # interpret-mode SpMV via pallas route: XLA-CPU compile cost
def test_spmv_routes_bit_identical_pallas_interpreter():
    """The xla route (jnp reference, the CPU default) matches the pallas
    route bit-for-bit through the dispatch seam: same scaling, residues,
    contraction, and Garner digits — routing by ``mode=``, never
    ``interpret=``.

    A 24-bit-payload plan (r = 7) keeps the interpreted Garner graph
    compileable in seconds; the default r = 15 plan's interpreted gather
    graph costs 10+ minutes of XLA-CPU compile (ROADMAP) regardless of
    problem size, so NO CPU lane covers it — on-TPU runs of the same tests
    exercise the compiled Mosaic kernel at the default plan.  Bit-identity is
    plan-independent (the decompose prologue is shared code and every integer
    step is exact), so this plan pins the whole path; ragged M exercises the
    row-padding of the fused kernel.
    """
    from repro.core import ozaki2
    plan = ozaki2.make_plan(4, payload_bits=24)
    val, col, x = _random_bell(27, 32, 4)    # 27 % br != 0: padding path
    for rep in ("f64", "digits"):
        y_ref = np.asarray(ops.ozaki_spmv_bell(val, col, x, plan=plan,
                                               out_rep=rep, mode="xla"))
        y_pal = np.asarray(ops.ozaki_spmv_bell(val, col, x, plan=plan, br=8,
                                               out_rep=rep, mode="pallas"))
        np.testing.assert_array_equal(y_ref, y_pal)
