import jax.numpy as jnp
import numpy as np

from repro.core import numerics as N

RNG = np.random.default_rng(3)


def test_two_sum_eft():
    a = jnp.asarray(RNG.standard_normal(100) * 1e10)
    b = jnp.asarray(RNG.standard_normal(100) * 1e-10)
    s, e = N.two_sum(a, b)
    # a + b == s + e exactly: check via exact reconstruction in extended precision
    import math
    for ai, bi, si, ei in zip(np.asarray(a), np.asarray(b), np.asarray(s), np.asarray(e)):
        assert float(si) + float(ei) == math.fsum([float(ai), float(bi)]) or \
            (float(si), float(ei)) == (float(ai) + float(bi), 0.0) or \
            abs(float(si) + float(ei) - (float(ai) + float(bi))) == 0.0


def test_two_prod_eft():
    a = jnp.asarray(RNG.standard_normal(64))
    b = jnp.asarray(RNG.standard_normal(64))
    p, e = N.two_prod(a, b)
    from fractions import Fraction
    for ai, bi, pi, ei in zip(np.asarray(a), np.asarray(b), np.asarray(p), np.asarray(e)):
        exact = Fraction(float(ai)) * Fraction(float(bi))
        assert Fraction(float(pi)) + Fraction(float(ei)) == exact


def test_kahan_beats_naive_f32():
    x = RNG.standard_normal(200000).astype(np.float32)
    exact = np.sum(x.astype(np.float64))
    naive = np.float32(0)
    for chunk in np.split(x, 100):
        naive += chunk.sum(dtype=np.float32)
    kah = float(N.kahan_sum(jnp.asarray(x)))
    assert abs(kah - exact) <= abs(float(naive) - exact) + 1e-3
    assert abs(kah - exact) / max(abs(exact), 1) < 1e-5


def test_compensated_dot_fp32_path():
    """§7.1(a): FP32+compensation reaches far beyond bare-f32 accuracy for BLAS-1."""
    n = 4096
    x = RNG.standard_normal(n).astype(np.float32)
    y = RNG.standard_normal(n).astype(np.float32)
    exact = float(np.dot(x.astype(np.float64), y.astype(np.float64)))
    comp = float(N.compensated_dot(jnp.asarray(x), jnp.asarray(y)))
    plain = float(jnp.dot(jnp.asarray(x), jnp.asarray(y)))
    assert abs(comp - exact) <= abs(plain - exact)
    assert abs(comp - exact) <= 64 * abs(exact) * 2 ** -24 + 1e-6


def test_double_single_roundtrip():
    x = jnp.asarray(RNG.standard_normal(1000) * 10.0 ** RNG.integers(-20, 20, 1000))
    hi, lo = N.ds_from_f64(x)
    assert hi.dtype == jnp.float32 and lo.dtype == jnp.float32
    back = np.asarray(N.ds_to_f64(hi, lo))
    np.testing.assert_allclose(back, np.asarray(x), rtol=2.0 ** -45)


def test_ds_add():
    a = N.ds_from_f64(jnp.asarray([1.0 + 2 ** -30]))
    b = N.ds_from_f64(jnp.asarray([2 ** -31]))
    s = N.ds_add(a, b)
    got = float(N.ds_to_f64(*s)[0])
    assert abs(got - (1.0 + 2 ** -30 + 2 ** -31)) < 2 ** -44
