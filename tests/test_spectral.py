"""Spectral subsystem: DFT-as-GEMM + Bailey four-step on the dispatch seam.

The acceptance contract: ``spectral.fft`` matches the ``jnp.fft.fft`` FP64
oracle to <= 1e-12 relative error for n in {64, 256, 1024, 12*32} on both
dispatch routes, with every multiplication flowing through
``repro.core.dispatch`` (no raw matmul anywhere in ``src/repro/spectral/``).
"""

import pathlib
import re

import jax.numpy as jnp
import numpy as np
import pytest

from repro import spectral
from repro.core import dispatch
from repro.spectral import bailey, dft

RNG = np.random.default_rng(11)

ACCEPTANCE_SIZES = (64, 256, 1024, 12 * 32)


def _rel(got, want):
    got, want = np.asarray(got), np.asarray(want)
    return np.linalg.norm(got - want) / np.linalg.norm(want)


def _rand_complex(*shape):
    return jnp.asarray(RNG.standard_normal(shape)
                       + 1j * RNG.standard_normal(shape))


# ---------------------------------------------------------------------------
# Acceptance: oracle match on both dispatch routes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", ACCEPTANCE_SIZES)
@pytest.mark.parametrize("mode", ["xla", "pallas"])
def test_fft_matches_jnp_oracle(n, mode):
    x = _rand_complex(n)
    with dispatch.mode_scope(mode):
        got = spectral.fft(x)
    assert _rel(got, jnp.fft.fft(x)) <= 1e-12


def test_fft_dispatch_routes_bit_identical():
    """XLA and Pallas routes agree bit-for-bit, transform-wide."""
    x = _rand_complex(256)
    y_xla = np.asarray(spectral.fft(x, mode="xla"))
    y_pal = np.asarray(spectral.fft(x, mode="pallas"))
    np.testing.assert_array_equal(y_xla, y_pal)


def test_every_multiplication_routes_through_dispatch(monkeypatch):
    """All spectral MACs flow through dispatch.matmul (counted via wrapper)."""
    calls = {"n": 0}
    real = dispatch.matmul

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(dispatch, "matmul", counting)
    spectral.fft(_rand_complex(256))
    # four-step on 256 = 16*16: one GEMM per pass, recursion bottoms out dense
    assert calls["n"] >= 2


def test_no_raw_matmul_in_spectral_source():
    """The subsystem contract, enforced at the source level."""
    pkg = pathlib.Path(spectral.__file__).parent
    forbidden = re.compile(
        r"jnp\.(dot|matmul|einsum|vdot|inner|tensordot)\(|lax\.dot|np\.dot\(|\S @ \S")
    for py in sorted(pkg.glob("*.py")):
        hits = forbidden.findall(py.read_text())
        assert not hits, f"raw matmul in {py.name}: {hits}"


# ---------------------------------------------------------------------------
# Transform semantics vs the jnp.fft oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [8, 30, 97, 120])
def test_fft_small_and_prime_sizes(n):
    """Dense path (incl. the prime fallback at 97) matches the oracle."""
    x = _rand_complex(n)
    assert _rel(spectral.fft(x), jnp.fft.fft(x)) <= 1e-12


def test_ifft_roundtrip_and_oracle():
    x = _rand_complex(384)
    assert _rel(spectral.ifft(x), jnp.fft.ifft(x)) <= 1e-12
    assert _rel(spectral.ifft(spectral.fft(x)), x) <= 1e-12


def test_fft_along_leading_axis_batched():
    x = _rand_complex(64, 5)
    got = spectral.fft(x, axis=0)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.fft.fft(x, axis=0)),
                               rtol=0, atol=1e-11)


def test_rfft_rejects_complex_input():
    with pytest.raises(ValueError):
        spectral.rfft(_rand_complex(64))


def test_rfft_matches_oracle():
    x = jnp.asarray(RNG.standard_normal(384))
    got = spectral.rfft(x)
    want = jnp.fft.rfft(x)
    assert got.shape == want.shape == (193,)
    assert _rel(got, want) <= 1e-12


@pytest.mark.parametrize("n", [64, 97, 384])
def test_irfft_roundtrip(n):
    x = jnp.asarray(RNG.standard_normal(n))
    back = spectral.irfft(spectral.rfft(x), n=n)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               rtol=0, atol=1e-11)


@pytest.mark.parametrize("n", [8, 12, 17, 32])
def test_irfft_truncation_and_padding_semantics(n):
    """n below/above 2(m-1), incl. odd n, follows the numpy half-spectrum."""
    h = _rand_complex(9)
    np.testing.assert_allclose(np.asarray(spectral.irfft(h, n=n)),
                               np.asarray(jnp.fft.irfft(h, n=n)),
                               rtol=0, atol=1e-12)


def test_fft2_and_fftn_match_oracle():
    x = _rand_complex(24, 32)
    assert _rel(spectral.fft2(x), jnp.fft.fft2(x)) <= 1e-12
    x3 = _rand_complex(8, 12, 16)
    assert _rel(spectral.fftn(x3), jnp.fft.fftn(x3)) <= 1e-12
    assert _rel(spectral.ifftn(spectral.fftn(x3)), x3) <= 1e-12


def test_fftn_axis_subset():
    x = _rand_complex(6, 64, 10)
    got = spectral.fftn(x, axes=(1,))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.fft.fft(x, axis=1)),
                               rtol=0, atol=1e-11)


# ---------------------------------------------------------------------------
# Factorisation / operator plumbing
# ---------------------------------------------------------------------------

def test_choose_factors_balanced():
    assert bailey.choose_factors(1024) == (32, 32)
    assert bailey.choose_factors(384) == (16, 24)
    assert bailey.choose_factors(97) is None          # prime
    n1, n2 = bailey.choose_factors(256)
    assert n1 * n2 == 256 and n1 <= n2


def test_realified_dft_block_structure():
    n = 16
    op = np.asarray(spectral.realified_dft(n))
    f = spectral.dft_matrix(n)
    np.testing.assert_allclose(op[:n, :n], f.real, atol=1e-15)
    np.testing.assert_allclose(op[:n, n:], -f.imag, atol=1e-15)
    np.testing.assert_allclose(op[n:, :n], f.imag, atol=1e-15)
    np.testing.assert_allclose(op[n:, n:], f.real, atol=1e-15)


def test_dense_fallback_refuses_huge_prime():
    with pytest.raises(ValueError):
        dft.realified_dft(dft.DENSE_HARD_MAX + 7)


def test_parseval_energy_preserved():
    x = _rand_complex(384)
    ex = float(jnp.sum(jnp.abs(x) ** 2))
    ef = float(jnp.sum(jnp.abs(spectral.fft(x)) ** 2)) / 384
    assert abs(ex - ef) / ex <= 1e-12


# ---------------------------------------------------------------------------
# Property tests (optional hypothesis dep)
# ---------------------------------------------------------------------------

def test_fft_factored_sizes_property():
    hyp = pytest.importorskip("hypothesis",
                              reason="optional dep: pip install -e .[test]")
    given, settings, st = hyp.given, hyp.settings, hyp.strategies

    @settings(max_examples=15, deadline=None)
    @given(n1=st.integers(2, 12), n2=st.integers(2, 12),
           seed=st.integers(0, 2 ** 31 - 1))
    def check(n1, n2, seed):
        """Any composite n = n1*n2 (incl. non-powers-of-two) hits the oracle."""
        n = n1 * n2
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal(n) + 1j * rng.standard_normal(n))
        assert _rel(bailey.dft_stacked(x[:, None])[:, 0],
                    jnp.fft.fft(x)) <= 1e-12

    check()
