"""Spectral Poisson solver: the FFT dwarf composed into the solver layer."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.hpc import poisson

RNG = np.random.default_rng(9)


@pytest.mark.parametrize("shape", [(64,), (24, 32), (8, 12, 16)])
def test_manufactured_solution_roundtrip(shape):
    """Solve Δu = f for f built from a known zero-mean u; recover u exactly."""
    f, u_exact = poisson.manufactured_rhs(shape, seed=2)
    u = poisson.poisson_solve_periodic(f)
    np.testing.assert_allclose(np.asarray(u), np.asarray(u_exact),
                               rtol=0, atol=1e-10)


def test_checked_solve_reports_true_residual():
    f = jnp.asarray(RNG.standard_normal((32, 32)))
    res = poisson.poisson_solve_checked(f)
    assert res.residual <= 1e-12
    assert abs(float(jnp.mean(res.u))) <= 1e-12     # zero-mean gauge


def test_matches_dense_periodic_laplacian_solve():
    """Against the dense operator: Δ_h u equals the mean-projected rhs."""
    n = 24
    f = jnp.asarray(RNG.standard_normal(n))
    u = poisson.poisson_solve_periodic(f)
    lap = (np.diag(-2.0 * np.ones(n)) + np.diag(np.ones(n - 1), 1)
           + np.diag(np.ones(n - 1), -1))
    lap[0, -1] = lap[-1, 0] = 1.0                   # periodic wrap
    rhs = np.asarray(f) - float(jnp.mean(f))
    np.testing.assert_allclose(lap @ np.asarray(u), rhs, rtol=0, atol=1e-11)


def test_grid_spacing_scales_solution():
    f, u_exact = poisson.manufactured_rhs((48,), spacings=[0.25], seed=4)
    u = poisson.poisson_solve_periodic(f, spacings=[0.25])
    np.testing.assert_allclose(np.asarray(u), np.asarray(u_exact),
                               rtol=0, atol=1e-10)


def test_laplacian_eigenvalues_zero_mode_only():
    lam = poisson.laplacian_eigenvalues((16, 16))
    assert lam[0, 0] == 0.0
    assert np.sum(lam == 0.0) == 1
    assert np.all(lam <= 0.0)


def test_odd_extension_structure():
    f = jnp.asarray(RNG.standard_normal((3, 4)))
    g = np.asarray(poisson.odd_extension(f))
    assert g.shape == (8, 10)
    assert abs(g.sum()) <= 1e-12                      # exactly zero mean
    np.testing.assert_array_equal(g[1:4, 1:5], np.asarray(f))  # interior embed
    assert np.all(g[0] == 0.0) and np.all(g[4] == 0.0)         # Dirichlet nodes
    # antisymmetry about the boundary plane on each axis
    np.testing.assert_array_equal(g[5:], -g[1:4][::-1])
    np.testing.assert_array_equal(g[:, 6:], -g[:, 1:5][:, ::-1])


def test_dirichlet_solve_matches_dense_1d():
    """Against the dense tridiagonal Dirichlet Laplacian (h = 1)."""
    n = 16
    f = jnp.asarray(RNG.standard_normal(n))
    u = poisson.poisson_solve_dirichlet(f)
    lap = (np.diag(-2.0 * np.ones(n)) + np.diag(np.ones(n - 1), 1)
           + np.diag(np.ones(n - 1), -1))
    np.testing.assert_allclose(np.asarray(u), np.linalg.solve(lap, np.asarray(f)),
                               rtol=0, atol=1e-11)


def test_dirichlet_solve_satisfies_stencil_operator_3d():
    """The restricted solution satisfies the zero-halo 7-point operator the
    stencil kernel applies — the contract jacobi_solve relaxes against."""
    from repro.hpc import jacobi

    f = jnp.asarray(RNG.standard_normal((5, 6, 4)))
    u = poisson.poisson_solve_dirichlet(f, spacings=(0.5, 0.5, 0.5))
    back = jacobi.apply_dirichlet_laplacian(u, spacings=(0.5, 0.5, 0.5))
    np.testing.assert_allclose(np.asarray(back), np.asarray(f),
                               rtol=0, atol=1e-9)
