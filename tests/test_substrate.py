"""Substrate tests: data pipeline, optimizer, checkpointing, fault tolerance,
gradient compression, serving engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data.pipeline import DataConfig, Pipeline, synth_batch
from repro.distributed import compression
from repro.models.transformer import Model
from repro.optim import adamw
from repro.train import checkpoint, fault_tolerance
from repro.train.loop import make_train_step


CFG = registry.get_config("yi-6b", smoke=True)


# --- data pipeline -----------------------------------------------------------

def test_pipeline_deterministic_given_step():
    dc = DataConfig(global_batch=4, seq_len=16, seed=7)
    b1 = synth_batch(dc, CFG, step=3)
    b2 = synth_batch(dc, CFG, step=3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = synth_batch(dc, CFG, step=4)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_pipeline_host_sharding_disjoint():
    full = DataConfig(global_batch=8, seq_len=16, num_hosts=1, host_id=0)
    h0 = DataConfig(global_batch=8, seq_len=16, num_hosts=2, host_id=0)
    h1 = DataConfig(global_batch=8, seq_len=16, num_hosts=2, host_id=1)
    b0 = synth_batch(h0, CFG, 0)
    b1 = synth_batch(h1, CFG, 0)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(b0["tokens"]), np.asarray(b1["tokens"]))


def test_pipeline_prefetch_and_resume():
    dc = DataConfig(global_batch=2, seq_len=8)
    p = Pipeline(dc, CFG, start_step=0)
    a = next(p)
    b = next(p)
    p.close()
    p2 = Pipeline(dc, CFG, start_step=1)
    b_resumed = next(p2)
    p2.close()
    np.testing.assert_array_equal(np.asarray(b["tokens"]),
                                  np.asarray(b_resumed["tokens"]))


def test_labels_are_learnable_structure():
    """Synthetic data has next-token structure (loss can go below uniform)."""
    dc = DataConfig(global_batch=4, seq_len=16)
    b = synth_batch(dc, CFG, 0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


# --- optimizer ----------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    cfg = adamw.AdamWConfig(lr=0.3, weight_decay=0.0)
    state = adamw.adamw_init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = adamw.adamw_update(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adamw_bf16_moments():
    params = {"w": jnp.ones((4, 4))}
    cfg = adamw.AdamWConfig(moment_dtype="bfloat16")
    st = adamw.adamw_init(params, cfg)
    assert st["m"]["w"].dtype == jnp.bfloat16
    p2, st2 = adamw.adamw_update(params, {"w": jnp.ones((4, 4))}, st, cfg)
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


def test_clip_by_global_norm():
    grads = {"a": jnp.full((3,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(300), rel=1e-5)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


# --- checkpointing --------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    model = Model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    checkpoint.save(d, 7, params, extra={"next_step": 8})
    restored, extra = checkpoint.restore(d, like=params)
    assert extra["next_step"] == 8
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, restored)


def test_checkpoint_latest_ignores_incomplete(tmp_path):
    model = Model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    checkpoint.save(d, 1, params)
    checkpoint.save(d, 5, params)
    # simulate a crash mid-write of step 9: directory without MANIFEST
    os.makedirs(os.path.join(d, "step_00000009"))
    assert checkpoint.latest_step(d) == 5


def test_checkpoint_detects_corruption(tmp_path):
    params = {"w": jnp.arange(10.0)}
    d = str(tmp_path / "ck")
    path = checkpoint.save(d, 0, params)
    # flip bytes in the shard
    f = os.path.join(path, "host0000.npz")
    data = bytearray(open(f, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(f, "wb").write(bytes(data))
    with pytest.raises(Exception):
        checkpoint.restore(d, like=params)


def test_checkpoint_cleanup(tmp_path):
    params = {"w": jnp.zeros(3)}
    d = str(tmp_path / "ck")
    for s in range(6):
        checkpoint.save(d, s, params)
    checkpoint.cleanup(d, keep=2)
    assert checkpoint.latest_step(d) == 5
    assert len([n for n in os.listdir(d) if n.startswith("step_")]) == 2


def test_async_writer(tmp_path):
    params = {"w": jnp.arange(5.0)}
    d = str(tmp_path / "ck")
    w = checkpoint.AsyncWriter()
    w.save(d, 3, params)
    w.wait()
    restored, _ = checkpoint.restore(d, like=params)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(params["w"]))


# --- fault tolerance -------------------------------------------------------------

def test_heartbeat_monitor():
    hb = fault_tolerance.HeartbeatMonitor(num_hosts=3, timeout_s=10)
    hb.beat(0, now=100.0)
    hb.beat(1, now=100.0)
    hb.beat(2, now=95.0)
    assert hb.dead_hosts(now=106.0) == [2]


def test_straggler_detector_flags_slow_host():
    det = fault_tolerance.StragglerDetector(num_hosts=8, patience=3)
    rng = np.random.default_rng(0)
    flagged = []
    for step in range(20):
        times = 1.0 + 0.01 * rng.standard_normal(8)
        times[5] = 3.0  # host 5 is 3x slower
        flagged = det.observe(times)
    assert flagged == [5]


def test_run_with_recovery_survives_failures(tmp_path):
    """Steps fail twice; training resumes from checkpoints and completes."""
    calls = {"n": 0}

    def step_fn(step, state):
        calls["n"] += 1
        if calls["n"] in (7, 15):       # two injected failures
            raise fault_tolerance.StepFailure("simulated node loss")
        return {"x": state["x"] + 1.0}, {}

    state, stats = fault_tolerance.run_with_recovery(
        step_fn, {"x": jnp.zeros(())}, num_steps=20,
        ckpt_dir=str(tmp_path / "ck"), ckpt_every=5,
        sleep=lambda s: None)
    assert stats["failures"] == 2
    assert stats["restores"] >= 2
    assert float(state["x"]) == 20.0    # exactly num_steps effective updates


# --- gradient compression ---------------------------------------------------------

def test_compression_error_feedback_preserves_mean():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    state = None
    total_raw = np.zeros((64, 64), np.float32)
    total_comp = np.zeros((64, 64), np.float32)
    for _ in range(50):
        comp, state = compression.compress_decompress(g, state)
        total_raw += np.asarray(g["w"])
        total_comp += np.asarray(comp["w"])
    # error feedback: accumulated compressed gradients track the true sum
    rel = np.abs(total_comp - total_raw).max() / np.abs(total_raw).max()
    assert rel < 0.01


def test_compression_ratio_near_4x():
    g = {"w": jnp.zeros((1024, 1024))}
    assert 3.5 < compression.compression_ratio(g) <= 4.0


def test_training_with_compression_converges():
    model = Model(CFG)
    params = model.init(jax.random.PRNGKey(1))
    step = make_train_step(model, compress_grads=True,
                           opt_cfg=adamw.AdamWConfig(lr=1e-3))
    opt = adamw.adamw_init(params)
    from repro.data.pipeline import DataConfig, synth_batch
    dc = DataConfig(global_batch=4, seq_len=16)
    comp_state = None
    losses = []
    for i in range(8):
        batch = synth_batch(dc, CFG, i % 2)
        params, opt, metrics, comp_state = step(params, opt, batch, comp_state)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


# --- serving engine -----------------------------------------------------------------

def test_continuous_batching_completes_requests():
    from repro.serve.engine import ContinuousBatcher, Request, ServeEngine
    model = Model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_slots=2, max_seq=32)
    cb = ContinuousBatcher(eng)
    rng = np.random.default_rng(0)
    for uid in range(4):                 # 4 requests > 2 slots: forces reuse
        cb.submit(Request(uid=uid,
                          prompt=rng.integers(0, CFG.vocab_size, 3).astype(np.int32),
                          max_new_tokens=4))
    done = cb.run_to_completion(max_steps=100)
    assert len(done) == 4
    for r in done:
        assert len(r.generated) >= 4
        assert all(0 <= t < CFG.vocab_size for t in r.generated)
