"""Dispatch layer: plan caching, XLA/Pallas routing, padding, mode override."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch, ozaki2
from repro.core.policy import Policy

U64 = 2.0 ** -53
RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# Plan cache
# ---------------------------------------------------------------------------

def test_get_plan_matches_make_plan():
    for k, p, sub in [(64, 53, "int8"), (300, 53, "fp8"), (64, 24, "int8")]:
        assert dispatch.get_plan(k, p, sub) == ozaki2.make_plan(k, p, substrate=sub)


def test_get_plan_is_cached_identity():
    a = dispatch.get_plan(96)
    b = dispatch.get_plan(96)
    assert a is b
    assert a.garner is b.garner  # Garner constants primed once, shared


def test_policy_dot_hot_path_skips_make_plan(monkeypatch):
    """After the cache is warm, Policy.dot never re-enters make_plan."""
    x = jnp.asarray(RNG.standard_normal((4, 48)))
    w = jnp.asarray(RNG.standard_normal((48, 4)))
    Policy("ozaki2_int8").dot(x, w)  # warm the (k=48, p=53, int8) entry

    calls = {"n": 0}
    real = ozaki2.make_plan

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(ozaki2, "make_plan", counting)
    for _ in range(3):
        Policy("ozaki2_int8").dot(x, w)
    assert calls["n"] == 0


def test_plan_cache_distinguishes_substrate_and_payload():
    assert dispatch.get_plan(64, 53, "int8") is not dispatch.get_plan(64, 53, "fp8")
    assert dispatch.get_plan(64, 53, "int8") is not dispatch.get_plan(64, 24, "int8")


# ---------------------------------------------------------------------------
# Mode resolution / env override
# ---------------------------------------------------------------------------

def test_env_var_selects_mode(monkeypatch):
    monkeypatch.setenv(dispatch.ENV_VAR, "pallas")
    assert dispatch.get_mode() == "pallas"
    monkeypatch.setenv(dispatch.ENV_VAR, "xla")
    assert dispatch.get_mode() == "xla"
    monkeypatch.delenv(dispatch.ENV_VAR)
    assert dispatch.get_mode() == "auto"


def test_invalid_mode_rejected(monkeypatch):
    monkeypatch.setenv(dispatch.ENV_VAR, "cuda")
    with pytest.raises(ValueError):
        dispatch.get_mode()
    with pytest.raises(ValueError):
        dispatch.set_mode("fast")


def test_mode_scope_overrides_env_and_restores(monkeypatch):
    monkeypatch.setenv(dispatch.ENV_VAR, "xla")
    with dispatch.mode_scope("pallas"):
        assert dispatch.get_mode() == "pallas"
        with dispatch.mode_scope(None):     # None inherits
            assert dispatch.get_mode() == "pallas"
    assert dispatch.get_mode() == "xla"


def test_choose_route():
    int8 = dispatch.get_plan(64, substrate="int8")
    fp8 = dispatch.get_plan(64, substrate="fp8")
    assert dispatch.choose_route(int8, mode="xla") == "xla"
    assert dispatch.choose_route(int8, mode="pallas") == "pallas"
    # fp8 has no fused kernel: always the XLA reference path
    assert dispatch.choose_route(fp8, mode="pallas") == "xla"
    # auto on this CPU container avoids interpret-mode Pallas
    if jax.default_backend() != "tpu":
        assert dispatch.choose_route(int8, mode="auto") == "xla"


def test_choose_route_is_kind_aware():
    """Every fused-kernel kind resolves through the same seam: explicit modes
    win, fp8 falls back, and auto follows the per-kind backend table."""
    int8 = dispatch.get_plan(64, substrate="int8")
    fp8 = dispatch.get_plan(64, substrate="fp8")
    for kind in dispatch.KINDS:
        assert dispatch.choose_route(int8, kind, "xla") == "xla"
        assert dispatch.choose_route(int8, kind, "pallas") == "pallas"
        assert dispatch.choose_route(fp8, kind, "pallas") == "xla"
        table = dispatch.AUTO_ROUTE[kind]
        want = table.get(jax.default_backend(), table["default"])
        assert dispatch.choose_route(int8, kind, "auto") == want
    with pytest.raises(ValueError):
        dispatch.choose_route(int8, "conv3x3")
    with pytest.raises(ValueError):
        dispatch.pallas_interpret("conv3x3")


def test_matmul_kind_split_matches_gemv_threshold():
    assert dispatch._matmul_kind(1) == "gemv"
    assert dispatch._matmul_kind(dispatch.GEMV_MAX_B) == "gemv"
    assert dispatch._matmul_kind(dispatch.GEMV_MAX_B + 1) == "gemm"


# ---------------------------------------------------------------------------
# Routing correctness
# ---------------------------------------------------------------------------

def test_pallas_route_bit_identical_evenly_tiled(monkeypatch):
    """REPRO_DISPATCH=pallas on an evenly-tiled f64 matmul == XLA bit-for-bit."""
    x = jnp.asarray(RNG.standard_normal((128, 256)))
    w = jnp.asarray(RNG.standard_normal((256, 128)))
    pol = Policy("ozaki2_int8")
    monkeypatch.setenv(dispatch.ENV_VAR, "xla")
    y_xla = np.asarray(pol.dot(x, w))
    monkeypatch.setenv(dispatch.ENV_VAR, "pallas")
    y_pal = np.asarray(pol.dot(x, w))
    np.testing.assert_array_equal(y_xla, y_pal)


@pytest.mark.parametrize("mkn", [(40, 70, 24), (8, 48, 8), (129, 257, 100)])
def test_pallas_route_padding_ragged_shapes(mkn):
    """Ragged shapes pad to MXU blocks; results stay bit-identical to XLA."""
    m, k, n = mkn
    a = jnp.asarray(RNG.standard_normal((m, k)))
    b = jnp.asarray(RNG.standard_normal((k, n)))
    y_xla = np.asarray(dispatch.matmul(a, b, mode="xla"))
    y_pal = np.asarray(dispatch.matmul(a, b, mode="pallas"))
    assert y_pal.shape == (m, n)
    np.testing.assert_array_equal(y_xla, y_pal)
    denom = np.abs(np.asarray(a)) @ np.abs(np.asarray(b)) + 1e-300
    want = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    assert np.max(np.abs(y_pal - want) / denom) <= 16 * U64


@pytest.mark.parametrize("n", [1, 8, dispatch.GEMV_MAX_B, dispatch.GEMV_MAX_B + 1])
def test_pallas_narrow_rhs_routes_via_gemv(n):
    """n <= GEMV_MAX_B uses the fused GEMV kernel; both sides bit-match XLA."""
    a = jnp.asarray(RNG.standard_normal((40, 64)))
    b = jnp.asarray(RNG.standard_normal((64, n)))
    y_xla = np.asarray(dispatch.matmul(a, b, mode="xla"))
    y_pal = np.asarray(dispatch.matmul(a, b, mode="pallas"))
    np.testing.assert_array_equal(y_xla, y_pal)


def test_pad_operands_blocks_divide_padded_shapes():
    a = jnp.zeros((40, 70))
    b = jnp.zeros((70, 24))
    ap, bp, (bm, bn, bk) = dispatch.pad_operands(a, b)
    assert ap.shape[0] % bm == 0 and ap.shape[1] % bk == 0
    assert bp.shape[0] % bk == 0 and bp.shape[1] % bn == 0
    assert ap.shape[0] % dispatch.SUBLANE == 0
    assert bp.shape[1] % dispatch.LANE == 0


def test_dispatch_dot_batched_leading_dims():
    x = jnp.asarray(RNG.standard_normal((3, 5, 32)))
    w = jnp.asarray(RNG.standard_normal((32, 16)))
    y = dispatch.dot(x, w, mode="pallas")
    want = np.asarray(x).reshape(-1, 32) @ np.asarray(w)
    np.testing.assert_allclose(np.asarray(y).reshape(-1, 16), want, rtol=1e-12)


def test_policy_grads_under_pallas_route(monkeypatch):
    """The custom VJP stays exact when the forward/backward route is fused."""
    monkeypatch.setenv(dispatch.ENV_VAR, "pallas")
    x = jnp.asarray(RNG.standard_normal((8, 32)))
    w = jnp.asarray(RNG.standard_normal((32, 8)))

    def loss(pol, a, b):
        return jnp.sum(pol.dot(a, b) ** 2)

    gx64, gw64 = jax.grad(lambda a, b: loss(Policy("fp64"), a, b), (0, 1))(x, w)
    gxe, gwe = jax.grad(
        lambda a, b: loss(Policy("ozaki2_int8"), a, b), (0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gxe), np.asarray(gx64), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(gwe), np.asarray(gw64), rtol=1e-12)


def test_fp8_policy_ignores_pallas_request(monkeypatch):
    """ozaki2_fp8 has no fused kernel; pallas mode falls back and stays exact."""
    monkeypatch.setenv(dispatch.ENV_VAR, "pallas")
    x = jnp.asarray(RNG.standard_normal((8, 64)))
    w = jnp.asarray(RNG.standard_normal((64, 8)))
    got = np.asarray(Policy("ozaki2_fp8").dot(x, w))
    want = np.asarray(x, np.float64) @ np.asarray(w, np.float64)
    denom = np.abs(np.asarray(x)) @ np.abs(np.asarray(w))
    assert np.max(np.abs(got - want) / denom) <= 16 * U64


def test_cg_dense_dispatch_converges():
    from repro.hpc import spmv_formats
    from repro.hpc.cg import cg_solve_dense

    dense = jnp.asarray(spmv_formats.laplacian_2d(6, 6))
    b = jnp.asarray(RNG.standard_normal(36))
    res = cg_solve_dense(dense, b, tol=1e-10)
    assert res.converged
    np.testing.assert_allclose(np.asarray(dense) @ np.asarray(res.x),
                               np.asarray(b), atol=1e-8)


# ---------------------------------------------------------------------------
# SpMV / stencil on the seam (mode flipping end-to-end)
# ---------------------------------------------------------------------------

def _spy_spmv_routes(monkeypatch):
    """Replace both SpMV routes with recorders (the pallas interpreter costs
    minutes of XLA-CPU compile, so the spy must intercept, not wrap)."""
    from repro.kernels import ozaki_spmv

    calls = []
    real_ref = ozaki_spmv.spmv_bell_ref

    def ref_spy(*a, **kw):
        calls.append("xla")
        return real_ref(*a, **kw)

    def pallas_spy(a_val, a_col, x, plan, out_rep="f64", br=128,
                   interpret=True):
        calls.append("pallas")
        assert interpret == dispatch.pallas_interpret("spmv_bell")
        return real_ref(a_val, a_col, x, plan, out_rep=out_rep)

    monkeypatch.setattr(ozaki_spmv, "spmv_bell_ref", ref_spy)
    monkeypatch.setattr(ozaki_spmv, "spmv_bell", pallas_spy)
    return calls


def test_mode_scope_flips_spmv_route(monkeypatch):
    """mode_scope / REPRO_DISPATCH select the route of ozaki_spmv_bell the
    same way they do for GEMM — no caller passes interpret= anymore."""
    from repro.kernels import ops

    calls = _spy_spmv_routes(monkeypatch)
    val = jnp.asarray(RNG.standard_normal((16, 4)))
    col = jnp.asarray(RNG.integers(0, 24, (16, 4)).astype(np.int32))
    x = jnp.asarray(RNG.standard_normal(24))

    with dispatch.mode_scope("xla"):
        ops.ozaki_spmv_bell(val, col, x)
    with dispatch.mode_scope("pallas"):
        ops.ozaki_spmv_bell(val, col, x)
    monkeypatch.setenv(dispatch.ENV_VAR, "pallas")
    ops.ozaki_spmv_bell(val, col, x)
    assert calls == ["xla", "pallas", "pallas"]


def test_mode_scope_flips_stencil_route(monkeypatch):
    from repro.kernels import ops, ozaki_stencil

    calls = []
    real_ref = ozaki_stencil.stencil7_ref

    def ref_spy(*a, **kw):
        calls.append("xla")
        return real_ref(*a, **kw)

    def pallas_spy(u, c, plan, out_rep="f64", bz=8, interpret=True):
        calls.append("pallas")
        assert interpret == dispatch.pallas_interpret("stencil7")
        return real_ref(u, c, plan, out_rep=out_rep)

    monkeypatch.setattr(ozaki_stencil, "stencil7_ref", ref_spy)
    monkeypatch.setattr(ozaki_stencil, "stencil7", pallas_spy)

    u = jnp.asarray(RNG.standard_normal((4, 4, 4)))
    c = jnp.asarray(np.array([6.0, -1, -1, -1, -1, -1, -1]))
    with dispatch.mode_scope("xla"):
        ops.ozaki_stencil7(u, c)
    with dispatch.mode_scope("pallas"):
        ops.ozaki_stencil7(u, c)
    monkeypatch.setenv(dispatch.ENV_VAR, "xla")
    ops.ozaki_stencil7(u, c)
    assert calls == ["xla", "pallas", "xla"]


def test_cg_solve_bell_rides_the_seam(monkeypatch):
    """The sparse-CG matvec goes through dispatch.spmv: mode_scope flips it."""
    from repro.hpc import spmv_formats
    from repro.hpc.cg import cg_solve_bell

    calls = _spy_spmv_routes(monkeypatch)
    dense = spmv_formats.laplacian_1d(12)
    val, col = spmv_formats.to_blocked_ell(dense, bw=4)
    b = jnp.asarray(RNG.standard_normal(12))
    with dispatch.mode_scope("pallas"):
        res = cg_solve_bell(jnp.asarray(val), jnp.asarray(col), b, tol=1e-10)
    assert res.converged
    assert calls and set(calls) == {"pallas"}


def test_stencil_routes_bit_identical():
    """xla vs pallas through dispatch.stencil7 — the cross-route parity the
    GEMM paths already pin, now for the structured-grid kind (all reps)."""
    u = jnp.asarray(RNG.standard_normal((10, 9, 11)))
    c = jnp.asarray(RNG.standard_normal(7))
    for rep in ("f64", "digits", "ds"):
        v_xla = np.asarray(dispatch.stencil7(u, c, out_rep=rep, mode="xla"))
        v_pal = np.asarray(dispatch.stencil7(u, c, out_rep=rep, bz=4,
                                             mode="pallas"))
        np.testing.assert_array_equal(v_xla, v_pal)


def test_spmv_routes_bit_identical_small_plan():
    """xla vs pallas through dispatch.spmv with a 24-bit-payload plan (r = 7):
    small enough for the interpreted gather graph to compile in seconds, so
    the fast lane pins SpMV cross-route parity too (a second r = 7 geometry —
    ragged M, both reps, via the ops entry point — runs in the slow lane:
    test_kernels.py; the default r = 15 plan is uncoverable on CPU, its
    interpreter compile exceeds 10 minutes regardless of problem size)."""
    plan = ozaki2.make_plan(4, payload_bits=24, margin_bits=4)
    val = jnp.asarray(RNG.standard_normal((24, 4)))
    col = jnp.asarray(RNG.integers(0, 32, (24, 4)).astype(np.int32))
    x = jnp.asarray(RNG.standard_normal(32))
    y_xla = np.asarray(dispatch.spmv(val, col, x, plan=plan, mode="xla"))
    y_pal = np.asarray(dispatch.spmv(val, col, x, plan=plan, br=8,
                                     mode="pallas"))
    np.testing.assert_array_equal(y_xla, y_pal)


# ---------------------------------------------------------------------------
# Autotuning table (get_tuning / REPRO_TUNE)
# ---------------------------------------------------------------------------

@pytest.fixture
def tune_env(monkeypatch):
    """Set REPRO_TUNE and clear the memoised lookups, restoring both after."""
    def setter(value):
        monkeypatch.setenv(dispatch.TUNE_VAR, value)
        dispatch.clear_tune_cache()
    yield setter
    dispatch.clear_tune_cache()


def test_shape_class_buckets_to_next_pow2():
    assert dispatch.shape_class((100, 64, 24)) == "128x64x32"
    assert dispatch.shape_class((4096,)) == "4096"
    assert dispatch.shape_class((1,)) == "1"


def test_get_tuning_specific_class_overrides_wildcard():
    assert dispatch.get_tuning("reduce", (4096,))["block"] == 512
    assert dispatch.get_tuning("reduce", (65536,))["block"] == 256
    # 40000 buckets to the 65536 class
    assert dispatch.reduce_block(40000) == 256
    assert dispatch.reduce_block(4096) == 512


def test_get_tuning_rejects_unknown_kind():
    with pytest.raises(ValueError, match="tuning kind"):
        dispatch.get_tuning("fft", (64,))


def test_repro_tune_inline_json_overrides(tune_env):
    tune_env('{"reduce": {"*": {"block": 64}, "1024": {"block": 32}}}')
    assert dispatch.reduce_block(4096) == 64
    assert dispatch.reduce_block(1000) == 32    # class-specific beats wildcard


def test_repro_tune_file(tmp_path, tune_env):
    p = tmp_path / "tune.json"
    p.write_text('{"reduce": {"*": {"block": 128}}}')
    tune_env(str(p))
    assert dispatch.reduce_block(4096) == 128


def test_repro_tune_unknown_kind_raises(tune_env):
    tune_env('{"warp_drive": {"*": {"block": 64}}}')
    with pytest.raises(ValueError, match="unknown kind"):
        dispatch.reduce_block(4096)


def test_tuned_route_pin_wins_in_auto_mode(tune_env):
    plan = dispatch.get_plan(64)  # int8 substrate: pallas-capable
    # CPU's AUTO_ROUTE default for gemm is xla; a tuned entry pins pallas.
    tune_env('{"gemm": {"*": {"route": "pallas"}}}')
    assert dispatch.choose_route(plan, "gemm", shape=(128, 64, 128)) == "pallas"
    # ... but an explicit mode still wins over the table.
    assert dispatch.choose_route(plan, "gemm", mode="xla",
                                 shape=(128, 64, 128)) == "xla"


def test_tuned_route_invalid_value_raises(tune_env):
    plan = dispatch.get_plan(64)
    tune_env('{"gemm": {"*": {"route": "auto"}}}')
    # mode="auto" pins the table-consulting path: an ambient
    # REPRO_DISPATCH=xla|pallas (the CI matrix) would short-circuit before
    # the tuned-route validation and the expected ValueError would not fire.
    with pytest.raises(ValueError, match="tuned route"):
        dispatch.choose_route(plan, "gemm", mode="auto", shape=(128, 64, 128))


def test_reduce_kind_has_no_pallas_route():
    assert not dispatch.pallas_supported(None, "reduce")
    assert dispatch.choose_route(None, "reduce", mode="pallas") == "xla"


def test_choose_blocks_tuned_values_are_legality_clamped(tune_env):
    tune_env('{"gemm": {"*": {"bm": 100, "bn": 100, "bk": 100}}}')
    bm, bn, bk = dispatch.choose_blocks(512, 512, 512)
    assert bm == 104          # rounded up to the sublane granule (8)
    assert bn == 128          # rounded up to the lane granule (128)
    assert bk == 128          # lane-rounded and dividing the padded K
    # A bad tuning entry degrades performance, never correctness/legality.
    assert bm % dispatch.SUBLANE == 0 and bn % dispatch.LANE == 0


def test_tuned_blocks_keep_pallas_route_bit_identical(tune_env):
    a = jnp.asarray(RNG.standard_normal((16, 48)))
    b = jnp.asarray(RNG.standard_normal((48, 8)))
    want = np.asarray(dispatch.matmul(a, b, mode="xla"))
    tune_env('{"gemv": {"*": {"bm": 8, "bk": 128}}}')
    got = np.asarray(dispatch.matmul(a, b, mode="pallas"))
    np.testing.assert_array_equal(want, got)
