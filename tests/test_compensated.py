"""Compensated reductions (repro.core.compensated): Neumaier sum, Dot2, nrm2."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compensated as C
from repro.core import numerics

RNG = np.random.default_rng(5)


def test_eft_reexports_are_the_numerics_primitives():
    assert C.two_sum is numerics.two_sum
    assert C.two_prod is numerics.two_prod
    assert C.fast_two_sum is numerics.fast_two_sum


def test_neumaier_recovers_cancellation_kahan_misses():
    """The classic Kahan failure case: a huge term arriving after small ones."""
    x = jnp.asarray([1.0, 1e100, 1.0, -1e100])
    assert float(C.neumaier_sum(x)) == 2.0


def test_neumaier_matches_fsum_ill_conditioned():
    vals = list(RNG.standard_normal(500) * 10.0 ** RNG.integers(-12, 12, 500))
    exact = math.fsum(vals)
    got = float(C.neumaier_sum(jnp.asarray(vals)))
    scale = math.fsum(abs(v) for v in vals)
    assert abs(got - exact) <= 4 * 2.0 ** -53 * scale


def test_neumaier_sum_axis():
    x = jnp.asarray(RNG.standard_normal((4, 64)))
    got = np.asarray(C.neumaier_sum(x, axis=-1))
    np.testing.assert_allclose(got, np.sum(np.asarray(x), axis=-1), rtol=1e-14)


def test_compensated_dot_twice_working_precision_f32():
    n = 4096
    x = RNG.standard_normal(n).astype(np.float32)
    y = RNG.standard_normal(n).astype(np.float32)
    exact = float(np.dot(x.astype(np.float64), y.astype(np.float64)))
    comp = float(C.compensated_dot(jnp.asarray(x), jnp.asarray(y)))
    plain = float(jnp.dot(jnp.asarray(x), jnp.asarray(y)))
    assert abs(comp - exact) <= abs(plain - exact)
    assert abs(comp - exact) <= 64 * abs(exact) * 2 ** -24 + 1e-6


def test_compensated_norm_matches_f64_oracle():
    x = RNG.standard_normal(2048).astype(np.float32)
    exact = float(np.linalg.norm(x.astype(np.float64)))
    got = float(C.compensated_norm(jnp.asarray(x)))
    assert abs(got - exact) <= 4 * exact * 2 ** -24


def test_compensated_norm_overflow_underflow_safe():
    big = jnp.asarray([1e200, 1e200, -1e200])
    assert np.isfinite(float(C.compensated_norm(big)))
    np.testing.assert_allclose(float(C.compensated_norm(big)),
                               1e200 * np.sqrt(3.0), rtol=1e-12)
    tiny = jnp.asarray([1e-300, 2e-300])
    np.testing.assert_allclose(float(C.compensated_norm(tiny)),
                               np.sqrt(5.0) * 1e-300, rtol=1e-12)
    assert float(C.compensated_norm(jnp.zeros(8))) == 0.0


def test_neumaier_vs_fsum_property():
    hyp = pytest.importorskip("hypothesis",
                              reason="optional dep: pip install -e .[test]")
    given, settings, st = hyp.given, hyp.settings, hyp.strategies

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(min_value=-1e15, max_value=1e15,
                              allow_nan=False, allow_infinity=False,
                              width=64),
                    min_size=1, max_size=64))
    def check(vals):
        """Neumaier summation tracks math.fsum to ~2 ulp of the term scale."""
        exact = math.fsum(vals)
        got = float(C.neumaier_sum(jnp.asarray(vals, jnp.float64)))
        scale = math.fsum(abs(v) for v in vals)
        assert abs(got - exact) <= 4 * 2.0 ** -53 * scale + 5e-324

    check()


# ---------------------------------------------------------------------------
# Blocked fast path vs the retained scan references (cross-implementation
# parity: exact or <= 1 ulp, asserted)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("n", [1, 7, 256, 1000, 4096])
def test_blocked_dot_matches_scan_reference(dtype, n):
    x = jnp.asarray(RNG.standard_normal(n).astype(dtype))
    y = jnp.asarray(RNG.standard_normal(n).astype(dtype))
    blocked = float(C.compensated_dot(x, y))
    scan = float(C.compensated_dot_scan(x, y))
    assert abs(blocked - scan) <= np.spacing(np.abs(scan).astype(dtype))


@pytest.mark.parametrize("block", [1, 3, 64, 4096, 10000])
def test_blocked_sum_matches_scan_any_block(block):
    vals = RNG.standard_normal(1000) * 10.0 ** RNG.integers(-10, 10, 1000)
    x = jnp.asarray(vals)
    blocked = float(C.neumaier_sum(x, block=block))
    scan = float(C.neumaier_sum_scan(x))
    exact = math.fsum(vals.tolist())
    scale = math.fsum(np.abs(vals).tolist())
    # Both land within the Sum2 bound of fsum; and within 1 ulp of each other.
    assert abs(blocked - exact) <= 4 * 2.0 ** -53 * scale
    assert abs(blocked - scan) <= np.spacing(abs(scan))


def test_batched_axis_variants_match_1d_loops():
    x = jnp.asarray(RNG.standard_normal((5, 300)))
    y = jnp.asarray(RNG.standard_normal((5, 300)))
    got = np.asarray(C.compensated_dot(x, y, axis=1))
    want = np.asarray([float(C.compensated_dot(x[i], y[i])) for i in range(5)])
    np.testing.assert_array_equal(got, want)

    got0 = np.asarray(C.neumaier_sum(x, axis=0))
    want0 = np.asarray([float(C.neumaier_sum(x[:, j])) for j in range(300)])
    np.testing.assert_array_equal(got0, want0)

    gotn = np.asarray(C.compensated_norm(x, axis=1))
    wantn = np.asarray([float(C.compensated_norm(x[i])) for i in range(5)])
    np.testing.assert_array_equal(gotn, wantn)


def test_dot_shape_mismatch_raises():
    with pytest.raises(ValueError, match="shapes differ"):
        C.compensated_dot(jnp.ones(4), jnp.ones(5))


def test_block_override_does_not_change_result_beyond_ulp():
    x = jnp.asarray(RNG.standard_normal(4096), jnp.float64)
    y = jnp.asarray(RNG.standard_normal(4096), jnp.float64)
    ref = float(C.compensated_dot(x, y, block=512))
    for block in (97, 256, 1024):
        got = float(C.compensated_dot(x, y, block=block))
        assert abs(got - ref) <= np.spacing(abs(ref))


# ---------------------------------------------------------------------------
# compensated_norm edge cases: denormal, huge, zero, non-finite
# ---------------------------------------------------------------------------

def test_norm_denormal_only_f32():
    """XLA CPU flushes denormal operands to zero (DAZ) — the bit-field scaling
    must recover the exact norm where plain arithmetic returns 0."""
    x = jnp.asarray([1e-40, 2e-40], jnp.float32)
    want = np.float32(math.hypot(float(x[0]), float(x[1])))
    assert float(C.compensated_norm(x)) == want
    assert want > 0.0
    # the single smallest denormal comes back exactly
    tiny = jnp.asarray([np.float32(1e-45)], jnp.float32)
    assert float(C.compensated_norm(tiny)) == float(tiny[0])


def test_norm_denormal_only_f64():
    x = jnp.asarray([5e-324, 1e-310], jnp.float64)
    got = float(C.compensated_norm(x))
    want = math.hypot(5e-324, 1e-310)
    assert got == want


def test_norm_huge_does_not_overflow():
    x = jnp.asarray([1e200, -1e200, 1e200], jnp.float64)
    np.testing.assert_allclose(float(C.compensated_norm(x)),
                               math.sqrt(3.0) * 1e200, rtol=1e-15)
    xf = jnp.asarray([1e38, 1e38], jnp.float32)
    np.testing.assert_allclose(float(C.compensated_norm(xf)),
                               np.float32(math.sqrt(2.0) * 1e38), rtol=1e-6)


def test_norm_mixed_magnitudes_track_hypot():
    x = jnp.asarray([1e-300, 1.0, 1e300], jnp.float64)
    np.testing.assert_allclose(float(C.compensated_norm(x)), 1e300, rtol=1e-15)


@pytest.mark.parametrize("vals,want", [
    ([1.0, np.inf], np.inf),
    ([1.0, -np.inf], np.inf),
    ([np.inf, -np.inf], np.inf),
])
def test_norm_inf_contaminated(vals, want):
    got = float(C.compensated_norm(jnp.asarray(vals, jnp.float64)))
    assert got == want


@pytest.mark.parametrize("vals", [[np.nan], [1.0, np.nan], [np.inf, np.nan]])
def test_norm_nan_dominates(vals):
    assert math.isnan(float(C.compensated_norm(jnp.asarray(vals))))


def test_norm_genuine_overflow_is_inf():
    x = jnp.asarray([1.7e308, 1.7e308], jnp.float64)
    assert float(C.compensated_norm(x)) == np.inf


def test_norm_unsupported_dtype_raises():
    with pytest.raises(TypeError, match="unsupported dtype"):
        C.compensated_norm(jnp.asarray([1, 2], jnp.bfloat16))


def test_norm_property_vs_hypot():
    hyp = pytest.importorskip("hypothesis",
                              reason="optional dep: pip install -e .[test]")
    given, settings, st = hyp.given, hyp.settings, hyp.strategies

    @settings(max_examples=80, deadline=None)
    @given(st.lists(st.floats(allow_nan=False, allow_infinity=False,
                              allow_subnormal=True, width=32),
                    min_size=1, max_size=32))
    def check(vals):
        """||x||_2 tracks math.hypot (correctly-rounded f64 oracle) to <= 2
        ulp across zero, denormal, and huge-magnitude f32 operands."""
        x = jnp.asarray(vals, jnp.float32)
        got = float(C.compensated_norm(x))
        want = np.float32(math.hypot(*(float(v) for v in np.asarray(x))))
        if np.isinf(want):
            assert got >= np.finfo(np.float32).max
        else:
            assert abs(got - want) <= 2 * np.spacing(want, dtype=np.float32)

    check()


def test_norm_property_vs_hypot_f64():
    hyp = pytest.importorskip("hypothesis",
                              reason="optional dep: pip install -e .[test]")
    given, settings, st = hyp.given, hyp.settings, hyp.strategies

    @settings(max_examples=80, deadline=None)
    @given(st.lists(st.floats(allow_nan=False, allow_infinity=False,
                              allow_subnormal=True, width=64),
                    min_size=1, max_size=32))
    def check(vals):
        got = float(C.compensated_norm(jnp.asarray(vals, jnp.float64)))
        want = math.hypot(*vals)
        if math.isinf(want):
            assert got >= np.finfo(np.float64).max
        else:
            assert abs(got - want) <= 2 * np.spacing(want)

    check()
