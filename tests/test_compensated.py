"""Compensated reductions (repro.core.compensated): Neumaier sum, Dot2, nrm2."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compensated as C
from repro.core import numerics

RNG = np.random.default_rng(5)


def test_eft_reexports_are_the_numerics_primitives():
    assert C.two_sum is numerics.two_sum
    assert C.two_prod is numerics.two_prod
    assert C.fast_two_sum is numerics.fast_two_sum


def test_neumaier_recovers_cancellation_kahan_misses():
    """The classic Kahan failure case: a huge term arriving after small ones."""
    x = jnp.asarray([1.0, 1e100, 1.0, -1e100])
    assert float(C.neumaier_sum(x)) == 2.0


def test_neumaier_matches_fsum_ill_conditioned():
    vals = list(RNG.standard_normal(500) * 10.0 ** RNG.integers(-12, 12, 500))
    exact = math.fsum(vals)
    got = float(C.neumaier_sum(jnp.asarray(vals)))
    scale = math.fsum(abs(v) for v in vals)
    assert abs(got - exact) <= 4 * 2.0 ** -53 * scale


def test_neumaier_sum_axis():
    x = jnp.asarray(RNG.standard_normal((4, 64)))
    got = np.asarray(C.neumaier_sum(x, axis=-1))
    np.testing.assert_allclose(got, np.sum(np.asarray(x), axis=-1), rtol=1e-14)


def test_compensated_dot_twice_working_precision_f32():
    n = 4096
    x = RNG.standard_normal(n).astype(np.float32)
    y = RNG.standard_normal(n).astype(np.float32)
    exact = float(np.dot(x.astype(np.float64), y.astype(np.float64)))
    comp = float(C.compensated_dot(jnp.asarray(x), jnp.asarray(y)))
    plain = float(jnp.dot(jnp.asarray(x), jnp.asarray(y)))
    assert abs(comp - exact) <= abs(plain - exact)
    assert abs(comp - exact) <= 64 * abs(exact) * 2 ** -24 + 1e-6


def test_compensated_norm_matches_f64_oracle():
    x = RNG.standard_normal(2048).astype(np.float32)
    exact = float(np.linalg.norm(x.astype(np.float64)))
    got = float(C.compensated_norm(jnp.asarray(x)))
    assert abs(got - exact) <= 4 * exact * 2 ** -24


def test_compensated_norm_overflow_underflow_safe():
    big = jnp.asarray([1e200, 1e200, -1e200])
    assert np.isfinite(float(C.compensated_norm(big)))
    np.testing.assert_allclose(float(C.compensated_norm(big)),
                               1e200 * np.sqrt(3.0), rtol=1e-12)
    tiny = jnp.asarray([1e-300, 2e-300])
    np.testing.assert_allclose(float(C.compensated_norm(tiny)),
                               np.sqrt(5.0) * 1e-300, rtol=1e-12)
    assert float(C.compensated_norm(jnp.zeros(8))) == 0.0


def test_neumaier_vs_fsum_property():
    hyp = pytest.importorskip("hypothesis",
                              reason="optional dep: pip install -e .[test]")
    given, settings, st = hyp.given, hyp.settings, hyp.strategies

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(min_value=-1e15, max_value=1e15,
                              allow_nan=False, allow_infinity=False,
                              width=64),
                    min_size=1, max_size=64))
    def check(vals):
        """Neumaier summation tracks math.fsum to ~2 ulp of the term scale."""
        exact = math.fsum(vals)
        got = float(C.neumaier_sum(jnp.asarray(vals, jnp.float64)))
        scale = math.fsum(abs(v) for v in vals)
        assert abs(got - exact) <= 4 * 2.0 ** -53 * scale + 5e-324

    check()
