"""Tests for the roofline tooling: jaxpr cost model + HLO collective parser."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch import cost_model, roofline


def test_dot_flops_exact():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    stats = cost_model.count(f, a, b)
    assert stats["flops"] == pytest.approx(2 * 64 * 128 * 32, rel=0.01)
    # traffic model: lhs + rhs + out bytes
    assert stats["hbm_bytes"] == (64 * 128 + 128 * 32 + 64 * 32) * 4


def test_scan_multiplies_by_length():
    """The whole point: XLA costs a scan body once; the jaxpr counter doesn't."""
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    def f(w, x):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((4, 16), jnp.float32)
    stats = cost_model.count(f, w, x)
    assert stats["flops"] == pytest.approx(10 * 2 * 4 * 16 * 16, rel=0.05)


def test_nested_scan():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci * 2.0, None
            ci, _ = jax.lax.scan(inner, c, None, length=5)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    x = jax.ShapeDtypeStruct((8,), jnp.float32)
    stats = cost_model.count(f, x)
    assert stats["flops"] == pytest.approx(3 * 5 * 8, rel=0.01)


def test_grad_includes_backward():
    def loss(w, x):
        return jnp.sum((x @ w) ** 2)

    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    fwd = cost_model.count(loss, w, x)["flops"]
    bwd = cost_model.count(jax.grad(loss, (0, 1)), w, x)["flops"]
    assert bwd > 2.5 * fwd  # fwd + dgrad + wgrad


def test_scan_state_bytes():
    def f(x):
        def body(c, _):
            return c * 1.5, c
        y, ys = jax.lax.scan(body, x, None, length=7)
        return y, ys

    x = jax.ShapeDtypeStruct((100,), jnp.float32)
    stats = cost_model.count(f, x)
    # 7 * (2 * carry 400B + ys slice 400B)
    assert stats["scan_state_bytes"] == 7 * (2 * 400 + 400)


def test_collective_parser():
    hlo = """
      %ag = bf16[8,1024]{1,0} all-gather(bf16[8,64]{1,0} %x), dims={1}
      %ar = f32[16,16]{1,0} all-reduce(f32[16,16]{1,0} %y), to_apply=%sum
      %rs = f32[4,8]{1,0} reduce-scatter(f32[4,64]{1,0} %z), dims={1}
      %t = (f32[8]{0}, f32[8]{0}) all-reduce(f32[8]{0} %a, f32[8]{0} %b)
      %p = u8[128]{0} collective-permute(u8[128]{0} %w), pairs={{0,1}}
      %st = f32[2]{0} all-gather-start(f32[1]{0} %q)
      %dn = f32[2]{0} all-gather-done(f32[2]{0} %st)
    """
    total, by_kind = roofline.collective_bytes_from_hlo(hlo)
    assert by_kind["all-gather"] == 8 * 1024 * 2 + 2 * 4
    assert by_kind["all-reduce"] == 2 * (16 * 16 * 4) + 2 * (2 * 8 * 4)
    assert by_kind["reduce-scatter"] == 4 * 8 * 4
    assert by_kind["collective-permute"] == 128
    assert total == sum(by_kind.values())


def test_model_flops_for():
    from repro.configs import registry
    from repro.configs.base import SHAPES_BY_NAME
    cfg = registry.get_config("yi-6b")
    n = cfg.active_param_count()
    train = roofline.model_flops_for(cfg, SHAPES_BY_NAME["train_4k"])
    assert train == pytest.approx(6 * n * 4096 * 256)
    dec = roofline.model_flops_for(cfg, SHAPES_BY_NAME["decode_32k"])
    assert dec == pytest.approx(2 * n * 128)


def test_cell_report_dominant():
    rep = roofline.CellReport(
        arch="x", shape="y", mesh="16x16", chips=256,
        hlo_flops=1e15, hlo_bytes=1e15, collective_bytes=1e12,
        collective_by_kind={}, per_device_peak_bytes=None,
        model_flops=8e14).finish()
    assert rep.dominant == "memory"          # bytes/819GB >> flops/197T
    assert 0 < rep.roofline_fraction < 1
    assert rep.useful_ratio == pytest.approx(0.8)
