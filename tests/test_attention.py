"""Fused emulated attention on the dispatch seam.

The contract of ``docs/dispatch-seam.md``, verified for the fifth kind:
cross-route bit-identity (the FlashAttention-style Pallas scan vs the
reference composed from seam GEMMs), FP64-oracle parity, and mode-flipping
end-to-end from the models/ and serve/ layers down to ``dispatch.attention``.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch

RNG = np.random.default_rng(11)


def _qkv(S, T, D, lead=()):
    q = jnp.asarray(RNG.standard_normal(lead + (S, D)))
    k = jnp.asarray(RNG.standard_normal(lead + (T, D)))
    v = jnp.asarray(RNG.standard_normal(lead + (T, D)))
    return q, k, v


def _oracle(q, k, v, mask=None, softcap=0.0):
    """Plain materialised-scores softmax attention at FP64."""
    q64, k64, v64 = (np.asarray(x, np.float64) for x in (q, k, v))
    s = q64 @ k64.T / math.sqrt(q.shape[-1])
    if softcap > 0:
        s = softcap * np.tanh(s / softcap)
    if mask is not None:
        s = np.where(np.asarray(mask).astype(bool), s, -1e30)
    p = np.exp(s - s.max(axis=-1, keepdims=True))
    p /= p.sum(axis=-1, keepdims=True)
    return p @ v64


# ---------------------------------------------------------------------------
# Cross-route bit-identity (the seam contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", ["causal", "padded", "decode", "softcap"])
def test_attention_routes_bit_identical(case):
    """xla vs pallas through dispatch.attention — bitwise equal, like every
    other kind on the seam (causal prefill, ragged padded T, decode S=1,
    and the softcapped variant)."""
    if case == "causal":
        q, k, v = _qkv(16, 16, 8)
        mask, softcap = jnp.tril(jnp.ones((16, 16), jnp.int8)), 0.0
    elif case == "padded":
        q, k, v = _qkv(9, 12, 8)        # ragged: pads to bkv internally
        mask = jnp.asarray((np.arange(12) < 10).astype(np.int8))[None, :]
        mask = jnp.broadcast_to(mask, (9, 12))
        softcap = 0.0
    elif case == "decode":
        q, k, v = _qkv(1, 12, 8)
        mask = jnp.asarray((np.arange(12) < 7).astype(np.int8))[None, :]
        softcap = 0.0
    else:
        q, k, v = _qkv(16, 16, 8)
        mask, softcap = jnp.tril(jnp.ones((16, 16), jnp.int8)), 30.0
    y_xla = np.asarray(dispatch.attention(q, k, v, mask=mask,
                                          softcap=softcap, mode="xla"))
    y_pal = np.asarray(dispatch.attention(q, k, v, mask=mask,
                                          softcap=softcap, mode="pallas"))
    np.testing.assert_array_equal(y_xla, y_pal)


def test_attention_batched_leading_dims_both_routes():
    """(..., S, D) leading dims map over independent rows; both routes agree
    with each slice computed alone."""
    q, k, v = _qkv(8, 12, 8, lead=(2, 2))
    mask = jnp.ones((8, 12), jnp.int8)
    y_xla = np.asarray(dispatch.attention(q, k, v, mask=mask, mode="xla"))
    y_pal = np.asarray(dispatch.attention(q, k, v, mask=mask, mode="pallas"))
    assert y_xla.shape == (2, 2, 8, 8)
    np.testing.assert_array_equal(y_xla, y_pal)
    one = np.asarray(dispatch.attention(q[1, 0], k[1, 0], v[1, 0], mask=mask,
                                        mode="xla"))
    np.testing.assert_array_equal(y_xla[1, 0], one)


# ---------------------------------------------------------------------------
# FP64-oracle parity (the emulation claim)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_attention_matches_fp64_oracle(softcap):
    """The seam-GEMM reference (and therefore, by bit-identity, the fused
    kernel) matches a plain jnp-free FP64 softmax-attention oracle to well
    under 1e-12 — the QK^T and PV products are exact, only the softmax
    transcendentals differ in evaluation order."""
    q, k, v = _qkv(16, 16, 8)
    mask = jnp.tril(jnp.ones((16, 16), jnp.int8))
    got = np.asarray(dispatch.attention(q, k, v, mask=mask, softcap=softcap,
                                        mode="xla"))
    want = _oracle(q, k, v, mask=mask, softcap=softcap)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_attention_no_mask_means_attend_all():
    q, k, v = _qkv(8, 8, 8)
    got = np.asarray(dispatch.attention(q, k, v, mode="xla"))
    np.testing.assert_allclose(got, _oracle(q, k, v), rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# Mode flipping end-to-end (spy: the routes themselves are intercepted)
# ---------------------------------------------------------------------------

def _spy_attention_routes(monkeypatch):
    """Replace both attention routes with recorders, delegating to the real
    reference so callers still get correct outputs (the fused interpreter at
    model shapes would dominate the fast lane otherwise)."""
    from repro.kernels import ozaki_attention

    calls = []
    real_ref = ozaki_attention.attention_ref

    def ref_spy(*a, **kw):
        calls.append("xla")
        return real_ref(*a, **kw)

    def pallas_spy(q, k, v, mask, plan_qk, plan_pv, softcap=0.0, bq=128,
                   bkv=128, interpret=True, out_dtype=jnp.float64):
        calls.append("pallas")
        assert interpret == dispatch.pallas_interpret("attention")
        return real_ref(q, k, v, mask, plan_qk, plan_pv, softcap=softcap,
                        bkv=bkv, out_dtype=out_dtype)

    monkeypatch.setattr(ozaki_attention, "attention_ref", ref_spy)
    monkeypatch.setattr(ozaki_attention, "attention_fused", pallas_spy)
    return calls


def test_mode_scope_flips_attention_route(monkeypatch):
    from repro.kernels import ops

    calls = _spy_attention_routes(monkeypatch)
    q, k, v = _qkv(8, 8, 8)
    with dispatch.mode_scope("xla"):
        ops.ozaki_attention(q, k, v)
    with dispatch.mode_scope("pallas"):
        ops.ozaki_attention(q, k, v)
    monkeypatch.setenv(dispatch.ENV_VAR, "pallas")
    ops.ozaki_attention(q, k, v)
    assert calls == ["xla", "pallas", "pallas"]


def test_model_attention_rides_the_seam(monkeypatch):
    """Under an emulated policy the whole model score path goes through
    dispatch.attention — mode_scope flips it like any seam multiplication."""
    from repro.configs import registry
    from repro.models.transformer import Model

    calls = _spy_attention_routes(monkeypatch)
    cfg = registry.get_config("yi-6b", smoke=True, policy_name="ozaki2_int8",
                              compute_dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (1, 4)).astype(np.int32))}
    with dispatch.mode_scope("xla"):
        logits, _ = model.apply(params, batch)
    assert calls and set(calls) == {"xla"}
    assert bool(jnp.all(jnp.isfinite(logits)))
    calls.clear()
    with dispatch.mode_scope("pallas"):
        model.apply(params, batch)
    assert calls and set(calls) == {"pallas"}


def test_serve_decode_attention_rides_the_seam(monkeypatch):
    """The engine's dispatch_mode pin reaches the fused attention kind inside
    the jitted decode step (the spy fires at trace time)."""
    from repro.configs import registry
    from repro.models.transformer import Model
    from repro.serve.engine import ServeEngine

    calls = _spy_attention_routes(monkeypatch)
    cfg = registry.get_config("yi-6b", smoke=True, policy_name="ozaki2_int8",
                              compute_dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_slots=1, max_seq=8,
                      dispatch_mode="pallas")
    prompt = RNG.integers(0, cfg.vocab_size, 2).astype(np.int32)
    eng.prefill_slot(0, prompt)
    assert calls and set(calls) == {"pallas"}


def test_model_emulated_matches_fp64_policy():
    """Emulated-policy logits track the fp64-policy model closely: the dense
    layers are FP64-exact by construction and the attention path differs only
    in softmax evaluation precision (f64 emulated vs f32 native)."""
    from repro.configs import registry
    from repro.models.transformer import Model

    batch = None
    outs = {}
    for pol in ("fp64", "ozaki2_int8"):
        cfg = registry.get_config("yi-6b", smoke=True, policy_name=pol,
                                  compute_dtype="float32")
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        if batch is None:
            batch = {"tokens": jnp.asarray(
                RNG.integers(0, cfg.vocab_size, (1, 4)).astype(np.int32))}
        with dispatch.mode_scope("xla"):
            outs[pol] = np.asarray(model.apply(params, batch)[0])
    np.testing.assert_allclose(outs["ozaki2_int8"], outs["fp64"],
                               rtol=1e-3, atol=1e-4)
