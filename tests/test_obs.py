"""Telemetry subsystem tests: mode resolution, recording tiers, tracer safety
(the instrumented entry points must still jit, bit-identically), cache
counters, solver residual traces, serving events, and the report/probe
surfaces."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compensated, dispatch, ozaki2
from repro.hpc import cg, jacobi
from repro.obs import report, telemetry as obs


@pytest.fixture(autouse=True)
def clean_telemetry(monkeypatch):
    """Every test starts with empty stores, no TLS override, and no ambient
    REPRO_TELEMETRY leaking in from the environment."""
    monkeypatch.delenv(obs.ENV_VAR, raising=False)
    obs.set_mode(None)
    obs.reset()
    yield
    obs.set_mode(None)
    obs.reset()


def _rng():
    return np.random.default_rng(0)


def _gemm_operands(n=32):
    rng = _rng()
    return (jnp.asarray(rng.standard_normal((n, n))),
            jnp.asarray(rng.standard_normal((n, n))))


# --- mode resolution ---------------------------------------------------------

def test_mode_default_off():
    assert obs.get_mode() == "off"
    assert not obs.enabled()
    assert not obs.tracing()


def test_mode_from_env(monkeypatch):
    monkeypatch.setenv(obs.ENV_VAR, "counters")
    assert obs.get_mode() == "counters"
    assert obs.enabled()
    assert not obs.tracing()


def test_mode_env_invalid_raises(monkeypatch):
    monkeypatch.setenv(obs.ENV_VAR, "loud")
    with pytest.raises(ValueError, match="telemetry mode"):
        obs.get_mode()


def test_set_mode_overrides_env(monkeypatch):
    monkeypatch.setenv(obs.ENV_VAR, "trace")
    obs.set_mode("off")
    assert obs.get_mode() == "off"
    obs.set_mode(None)
    assert obs.get_mode() == "trace"


def test_scope_nests_and_restores():
    with obs.telemetry_scope("counters"):
        assert obs.get_mode() == "counters"
        with obs.telemetry_scope("trace"):
            assert obs.tracing()
        with obs.telemetry_scope(None):      # None inherits
            assert obs.get_mode() == "counters"
        assert obs.get_mode() == "counters"
    assert obs.get_mode() == "off"


def test_scope_invalid_mode_raises():
    with pytest.raises(ValueError):
        with obs.telemetry_scope("verbose"):
            pass


# --- recording tiers ---------------------------------------------------------

def test_off_records_nothing():
    a, b = _gemm_operands()
    dispatch.matmul(a, b, mode="xla")
    assert obs.counters_snapshot() == {}
    assert obs.trace_snapshot() == []
    assert obs.cache_snapshot() == {}


def test_counters_mode_aggregates_without_trace():
    a, b = _gemm_operands()
    with obs.telemetry_scope("counters"):
        dispatch.matmul(a, b, mode="xla")
        dispatch.matmul(a, b, mode="xla")
    counters = obs.counters_snapshot()
    key = ("gemm", dispatch.shape_class((32, 32, 32)), "xla")
    assert key in counters
    agg = counters[key]
    assert agg["calls"] == 2
    assert agg["us"] > 0.0
    assert agg["us_min"] <= agg["us_max"] <= agg["us"]
    assert agg["flops"] == pytest.approx(2 * 2.0 * 32 ** 3)
    assert agg["tme_us"] > 0.0
    assert obs.trace_snapshot() == []        # ring only fills in trace mode


def test_trace_mode_fills_ring_with_plan_fields():
    a, b = _gemm_operands()
    with obs.telemetry_scope("trace"):
        dispatch.matmul(a, b, mode="xla")
    (ev,) = [e for e in obs.trace_snapshot() if e.kind == "gemm"]
    plan = dispatch.get_plan(32)
    assert ev.route == "xla"
    assert ev.r == plan.r
    assert ev.payload_bits == plan.payload_bits
    assert ev.us > 0.0 and ev.tme_us > 0.0
    assert ev.shape_class == dispatch.shape_class((32, 32, 32))


def test_all_dispatch_kinds_record(tmp_path):
    rng = _rng()
    a, b = _gemm_operands()
    v = jnp.asarray(rng.standard_normal((32, 2)))
    u = jnp.asarray(rng.standard_normal((8, 8, 8)))
    c = jnp.asarray(np.array([6.0, -1, -1, -1, -1, -1, -1]))
    plan_r7 = ozaki2.make_plan(4, payload_bits=24, margin_bits=4)
    val = jnp.asarray(rng.standard_normal((32, 4)))
    col = jnp.asarray(rng.integers(0, 32, (32, 4)).astype(np.int32))
    x = jnp.asarray(rng.standard_normal(32))
    q = jnp.asarray(rng.standard_normal((16, 8)))
    kq = jnp.asarray(rng.standard_normal((16, 8)))
    vq = jnp.asarray(rng.standard_normal((16, 8)))
    with obs.telemetry_scope("counters"):
        dispatch.matmul(a, b, mode="xla")
        dispatch.matmul(a, v, mode="xla")
        dispatch.stencil7(u, c, bz=4, mode="xla")
        dispatch.spmv(val, col, x, plan=plan_r7, br=8, mode="xla")
        dispatch.attention(q, kq, vq, mode="xla")
        compensated.compensated_dot(x, x)
    kinds = {k for (k, _, _) in obs.counters_snapshot()}
    assert {"gemm", "gemv", "stencil7", "spmv_bell", "attention",
            "reduce"} <= kinds


def test_attention_labels_prefill_vs_decode():
    rng = _rng()
    k = jnp.asarray(rng.standard_normal((16, 8)))
    v = jnp.asarray(rng.standard_normal((16, 8)))
    q_pre = jnp.asarray(rng.standard_normal((16, 8)))
    q_dec = jnp.asarray(rng.standard_normal((1, 8)))
    with obs.telemetry_scope("trace"):
        dispatch.attention(q_pre, k, v, mode="xla")
        dispatch.attention(q_dec, k, v, mode="xla")
    labels = [e.label for e in obs.trace_snapshot() if e.kind == "attention"]
    assert labels == ["prefill", "decode"]
    events = [e for e in obs.trace_snapshot() if e.kind == "attention"]
    assert all(e.tme_us > 0.0 for e in events)


def test_reduce_labels_cover_sum_dot_norm():
    x = jnp.asarray(_rng().standard_normal(256), jnp.float32)
    with obs.telemetry_scope("trace"):
        compensated.neumaier_sum(x)
        compensated.compensated_dot(x, x)
        compensated.compensated_norm(x)
    labels = [e.label for e in obs.trace_snapshot() if e.kind == "reduce"]
    # norm must record exactly one event (not a nested dot2 as well)
    assert labels == ["sum2", "dot2", "nrm2"]


def test_reset_clears_everything():
    a, b = _gemm_operands()
    with obs.telemetry_scope("trace"):
        dispatch.matmul(a, b, mode="xla")
        obs.record_event("custom", us=1.0)
    obs.reset()
    assert obs.counters_snapshot() == {}
    assert obs.trace_snapshot() == []
    assert obs.cache_snapshot() == {}


# --- tracer safety (satellite: bit-identity under jit) -----------------------

@pytest.mark.parametrize("op", ["matmul", "spmv", "stencil7", "attention",
                                "dot"])
def test_jit_bit_identical_and_silent(op):
    """Under jax.jit with telemetry on: nothing is recorded (operands are
    tracers) and the result is bit-identical to telemetry off."""
    rng = _rng()
    if op == "matmul":
        a, b = _gemm_operands()
        fn = jax.jit(lambda a, b: dispatch.matmul(a, b, mode="xla"))
        args = (a, b)
    elif op == "spmv":
        plan_r7 = ozaki2.make_plan(4, payload_bits=24, margin_bits=4)
        val = jnp.asarray(rng.standard_normal((32, 4)))
        col = jnp.asarray(rng.integers(0, 32, (32, 4)).astype(np.int32))
        x = jnp.asarray(rng.standard_normal(32))
        fn = jax.jit(lambda val, col, x: dispatch.spmv(
            val, col, x, plan=plan_r7, br=8, mode="xla"))
        args = (val, col, x)
    elif op == "stencil7":
        u = jnp.asarray(rng.standard_normal((8, 8, 8)))
        c = jnp.asarray(np.array([6.0, -1, -1, -1, -1, -1, -1]))
        fn = jax.jit(lambda u, c: dispatch.stencil7(u, c, bz=4, mode="xla"))
        args = (u, c)
    elif op == "attention":
        q = jnp.asarray(rng.standard_normal((16, 8)))
        k = jnp.asarray(rng.standard_normal((16, 8)))
        v = jnp.asarray(rng.standard_normal((16, 8)))
        fn = jax.jit(lambda q, k, v: dispatch.attention(q, k, v, mode="xla"))
        args = (q, k, v)
    else:
        x = jnp.asarray(rng.standard_normal(512), jnp.float32)
        fn = jax.jit(compensated.compensated_dot)
        args = (x, x)

    ref = jax.block_until_ready(fn(*args))        # telemetry off
    obs.reset()
    with obs.telemetry_scope("trace"):
        out = jax.block_until_ready(fn(*args))
        assert obs.counters_snapshot() == {}, "jitted call must record nothing"
        assert obs.trace_snapshot() == []
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_record_event_drops_tracer_payloads():
    @jax.jit
    def f(x):
        obs.record_event("inside", value=x)      # x is a tracer here
        return x * 2
    with obs.telemetry_scope("trace"):
        f(jnp.ones(4))
    assert all(e.kind != "inside" for e in obs.trace_snapshot())


# --- cache counters ----------------------------------------------------------

def test_plan_and_tune_cache_counters():
    dispatch.clear_plan_cache()
    dispatch.clear_tune_cache()
    with obs.telemetry_scope("counters"):
        dispatch.get_plan(24)
        dispatch.get_plan(24)
        dispatch.get_tuning("gemm", (16, 24, 16))
        dispatch.get_tuning("gemm", (16, 24, 16))
    caches = obs.cache_snapshot()
    assert caches["plan"] == (1, 1)              # (hits, misses)
    assert caches["tune"] == (1, 1)


# --- solver residual traces --------------------------------------------------

def test_cg_residual_trace_matches_history():
    rng = _rng()
    n = 12
    m = rng.standard_normal((n, n))
    a = jnp.asarray(m @ m.T + n * np.eye(n))
    b = jnp.asarray(rng.standard_normal(n))
    with obs.telemetry_scope("trace"):
        res = cg.cg_solve_dense(a, b, tol=1e-10, maxiter=2 * n, mode="xla",
                                record_plain=False)
    events = [e for e in obs.trace_snapshot() if e.kind == "solver.cg"]
    assert len(events) == len(res.history)
    iters = [dict(e.extra)["iter"] for e in events]
    assert iters == list(range(len(res.history)))
    rels = [dict(e.extra)["rel_residual"] for e in events]
    assert rels == pytest.approx(res.history)


def test_jacobi_residual_trace_matches_history():
    rng = _rng()
    f = jnp.asarray(rng.standard_normal((6, 6, 6)))
    with obs.telemetry_scope("trace"):
        res = jacobi.jacobi_solve(f, tol=1e-6, maxiter=50, mode="xla",
                                  check_every=5)
    events = [e for e in obs.trace_snapshot() if e.kind == "solver.jacobi"]
    assert len(events) == len(res.history)
    assert dict(events[0].extra)["rel_residual"] == pytest.approx(
        res.history[0])


# --- serving events ----------------------------------------------------------

def test_serve_engine_records_step_events():
    from repro.configs import registry
    from repro.models.transformer import Model
    from repro.serve.engine import ContinuousBatcher, Request, ServeEngine

    cfg = registry.get_config("yi-6b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_slots=2, max_seq=32)
    cb = ContinuousBatcher(eng)
    rng = _rng()
    with obs.telemetry_scope("trace"):
        cb.submit(Request(uid=0, max_new_tokens=2, prompt=rng.integers(
            0, cfg.vocab_size, 3).astype(np.int32)))
        done = cb.run_to_completion(max_steps=20)
    assert len(done) == 1
    events = obs.trace_snapshot()
    prefill = [e for e in events if e.kind == "serve.prefill"]
    decode = [e for e in events if e.kind == "serve.decode"]
    queue = [e for e in events if e.kind == "serve.queue"]
    assert len(prefill) == 1
    assert dict(prefill[0].extra)["tokens"] == 3
    assert prefill[0].us > 0.0
    assert dict(prefill[0].extra)["tokens_per_s"] > 0.0
    assert len(decode) >= 1 and all(e.us > 0.0 for e in decode)
    assert dict(queue[0].extra) == {"queued": 1, "active": 0}


# --- report / probe / snapshot -----------------------------------------------

def test_report_rows_and_render():
    a, b = _gemm_operands()
    with obs.telemetry_scope("counters"):
        dispatch.matmul(a, b, mode="xla")
        obs.record_event("solver.cg", dims=(16,), iter=0, rel_residual=1.0)
    rows = report.table_rows()
    by_kind = {r["kind"]: r for r in rows}
    assert by_kind["gemm"]["ratio"] > 0.0
    assert by_kind["solver.cg"]["ratio"] == 0.0   # no TME prediction
    text = report.render(rows, chip="TPUv5e")
    assert "gemm" in text and "TPUv5e" in text


def test_probe_returns_route_event():
    a, b = _gemm_operands()
    out, ev = obs.probe(lambda: dispatch.matmul(a, b, mode="pallas"))
    assert ev is not None
    assert ev.route == "pallas" and ev.kind == "gemm"
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(dispatch.matmul(a, b, mode="xla")))
    assert obs.get_mode() == "off"                # probe restores the mode


def test_probe_no_dispatch_returns_none():
    out, ev = obs.probe(lambda: jnp.ones(3) * 2)
    assert ev is None
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones(3))


def test_snapshot_json_roundtrip_and_report_main(tmp_path, capsys):
    a, b = _gemm_operands()
    with obs.telemetry_scope("trace"):
        dispatch.matmul(a, b, mode="xla")
        path = obs.write_json(str(tmp_path / "telemetry.json"))
    snap = json.loads((tmp_path / "telemetry.json").read_text())
    assert snap["mode"] == "trace"
    assert snap["counters"] and snap["trace"]
    assert snap["chip"] in ("TPUv5e", "H100", "B200", "B300", "R200")
    assert report.main([path]) == 0
    assert "gemm" in capsys.readouterr().out
    assert report.main([path, "--json"]) == 0
    rows = json.loads(capsys.readouterr().out)
    assert rows[0]["kind"] == "gemm"
