
import pytest

from repro.core import tme


P = tme.EmulationParams.ozaki2(r=10, substrate="fp8")


def test_table2_ridge_points():
    # Paper Table 2 bottom row: 10.1, 5.0, 0.16, 1.5 FLOPs/B.
    assert tme.H100.fp64_vector / tme.H100.hbm_tbps == pytest.approx(10.1, abs=0.1)
    assert tme.B200.fp64_vector / tme.B200.hbm_tbps == pytest.approx(5.0, abs=0.1)
    assert tme.B300.fp64_vector / tme.B300.hbm_tbps == pytest.approx(0.16, abs=0.01)
    assert tme.R200.fp64_vector / tme.R200.hbm_tbps == pytest.approx(1.5, abs=0.01)


def test_b300_emulation_ceiling():
    # §3: 5,000 / 10 = 500 TFLOPS dense on B300; 400 on Rubin.
    assert tme.emulated_perf(1000, tme.B300, P) == pytest.approx(500)
    assert tme.emulated_perf(1000, tme.R200, P) == pytest.approx(400)


def test_case_a_stencil_speedup():
    # §4.3 Case A worked example: I=0.5 on B300 -> 0.5*8/1.3 ≈ 3.1x.
    s = tme.speedup(0.5, tme.B300, P)
    assert s == pytest.approx(0.5 * 8 / 1.3, rel=1e-6)
    assert 3.0 < s < 3.2


def test_case_b_memory_bound_parity():
    # Case B: both memory-bound -> T_emu/T_nat -> β; fused β=1 gives parity.
    for spec in (tme.H100, tme.B200):
        assert tme.speedup(0.2, spec, P) == pytest.approx(1.0)
    unfused = tme.EmulationParams.ozaki2(r=10, substrate="fp8", fused=False)
    assert tme.speedup(0.2, tme.H100, unfused) == pytest.approx(1.0 / 10)


def test_case_c_compute_bound_gemm():
    # Case C on B300: ρ/α ≈ 5000/10/1.3 ≈ 380x (vs vector; table uses ~380).
    s = tme.speedup(1000, tme.B300, P, matrix=False)
    assert s == pytest.approx(500 / 1.3, rel=1e-6)


def test_table3_b300_column():
    rows = {r["workload"]: r for r in tme.table3_speedups()}
    assert rows["dense_gemm"]["B300"] == pytest.approx(500 / 1.2, rel=0.01)
    assert rows["bgemv_b8"]["B300"] == pytest.approx(24.6, rel=0.02)
    assert rows["bgemv_b2"]["B300"] == pytest.approx(9.2, rel=0.02)
    assert rows["stencil_7pt"]["B300"] == pytest.approx(3.1, rel=0.02)
    assert rows["spmv"]["B300"] == pytest.approx(1.23, rel=0.02)


def test_table4_key_cells():
    rows = tme.table4_h100_baseline()
    def cell(work, path, chip):
        for r in rows:
            if r["workload"] == work and r["path"] == path:
                return r[chip]
        raise KeyError

    # Paper Table 4 spot checks.
    assert cell("dense_gemm", "native", "H100") == pytest.approx(67)
    assert cell("dense_gemm", "ozaki2", "H100") == pytest.approx(198, rel=0.01)
    assert cell("dense_gemm", "ozaki2", "B300") == pytest.approx(500)
    assert cell("bgemv_b8", "ozaki2", "B300") == pytest.approx(32)
    assert cell("bgemv_b8", "ozaki2", "R200") == pytest.approx(88)
    assert cell("stencil_7pt", "ozaki2", "R200") == pytest.approx(11)
    assert cell("spmv", "ozaki2", "B300") == pytest.approx(1.6)
    # H100-relative: memory-bound rows on B300 = HBM ratio 8/3.35 = 2.39x.
    assert cell("stencil_7pt", "ozaki2", "B300") / cell("stencil_7pt", "native", "H100") \
        == pytest.approx(8 / 3.35, rel=0.01)
    # Rubin memory-bound rows = 22/3.35 = 6.57x.
    assert cell("spmv", "ozaki2", "R200") / cell("spmv", "native", "H100") \
        == pytest.approx(22 / 3.35, rel=0.01)


def test_table5():
    rows = {r["chip"]: r for r in tme.table5_substrates()}
    assert rows["H100"]["fp8_advantage"] == pytest.approx(1.0)
    assert rows["B300"]["fp8_advantage"] == pytest.approx(30.3, rel=0.02)
    assert rows["B200"]["fp8_advantage"] == pytest.approx(29.0, rel=0.02)
    assert rows["R200"]["fp8_advantage"] == pytest.approx(16.0, rel=0.02)
    assert rows["B300"]["ozaki_fp8_ceiling"] == pytest.approx(500)


def test_moduli_sensitivity_section_2_4():
    rows = {r["r"]: r for r in tme.moduli_sensitivity("B300")}
    # r=11: ceiling drops ~9% (500 -> ~455); r=12: ~17%.
    assert rows[11]["ceiling_r"] == pytest.approx(455, rel=0.01)
    assert rows[12]["ceiling_r"] == pytest.approx(417, rel=0.01)


def test_emulated_perf_never_exceeds_roofs():
    for oi in (0.01, 0.2, 1.5, 18, 100, 1e4):
        for spec in tme.CHIPS.values():
            e = tme.emulated_perf(oi, spec, P)
            assert e <= oi * spec.hbm_tbps + 1e-9
            assert e <= tme.p_low(spec, "fp8") / P.alpha + 1e-9


def test_emulation_ridge():
    # B300: P_fp8/(r·B_mem) = 5000/(10·8) = 62.5 F/B.
    assert tme.emulation_ridge(tme.B300, P) == pytest.approx(62.5)
    # §4.4's "I ≲ 18 FLOPS/B" figure corresponds to Rubin: 4000/(10·22) ≈ 18.2.
    assert tme.emulation_ridge(tme.R200, P) == pytest.approx(18.2, rel=0.01)


def test_roofline_terms():
    t = tme.roofline_terms(hlo_flops=1e15, hlo_bytes=1e12, collective_bytes=1e11,
                           chips=256)
    assert t.compute_s == pytest.approx(1e15 / (256 * 197e12))
    assert t.memory_s == pytest.approx(1e12 / (256 * 819e9))
    assert t.collective_s == pytest.approx(1e11 / (256 * 50e9))
    assert t.dominant == "compute"


def test_bailey_fft_stages_inventory():
    # 1024 = 32*32, both factors dense: one recursion level, two GEMM leaves.
    stages = tme.bailey_fft_stages(1024, batch=8)
    assert [s.name for s in stages] == ["gemm_n32", "twiddle_n1024",
                                        "transpose_n1024", "gemm_n32"]
    # each dense leaf: 8f MACs-worth of FLOPs per element of the full stack
    assert stages[0].W == stages[3].W == 8.0 * 32 * 1024 * 8
    # each GEMM pass reconstructs 2n real outputs per batch element
    assert stages[0].n_out == 2.0 * 1024 * 8
    assert stages[2].W == 0.0          # transpose is pure data movement


def test_bailey_fft_stages_recurse_like_the_executed_transform():
    """Model stages mirror dft_stacked's recursion: 2^18 -> 512*512 with each
    512 factored again (16*32), so GEMM leaves are all dense-sized."""
    from repro.spectral.dft import DENSE_MAX
    stages = tme.bailey_fft_stages(1 << 18)
    names = [s.name for s in stages]
    assert "twiddle_n262144" in names and "twiddle_n512" in names
    leaf_sizes = {int(s.name[len("gemm_n"):]) for s in stages
                  if s.name.startswith("gemm_n")}
    assert leaf_sizes == {16, 32}
    assert all(f <= DENSE_MAX for f in leaf_sizes)


def test_fft_gamma_term_not_silently_zero():
    """The per-stage gamma split must be visible under the model defaults."""
    rows = tme.table_fft(r=10, batch=4096, sizes=(1 << 18,))
    assert all(r["gamma_fraction"] > 0.0 for r in rows)
    assert all(r["gamma_fraction"] < 0.5 for r in rows)   # amortised, not dominant
    assert tme.garner_gamma(tme.B300, 10) == pytest.approx(100 / 165e12)


def test_fft_emulated_beats_native_on_post_fp64_chips():
    """The companion-paper claim in TME terms: emulation loses on H100's
    healthy FP64 pipe and wins on B300 where FP64 has collapsed."""
    import dataclasses
    for chip, expect_win in (("H100", False), ("B300", True)):
        spec = tme.CHIPS[chip]
        params = dataclasses.replace(
            tme.EmulationParams.ozaki2(r=10, substrate="fp8"),
            gamma=tme.garner_gamma(spec, 10))
        nat = tme.fft_native_time(1 << 18, spec, batch=4096)
        emu = tme.fft_emulated_time(1 << 18, spec, params, batch=4096)
        assert (nat / emu > 1.0) == expect_win


# --- native_ridge / telemetry prediction surface -----------------------------

def test_native_ridge_pins_h100_table2_value():
    """TFLOPS / (TB/s): the 1e12s cancel, leaving FLOPs/Byte — H100's Table 2
    ridge is 34/3.35 ≈ 10.1 F/B (regression pin for the old unit-fudge bug)."""
    assert tme.H100.native_ridge == pytest.approx(34 / 3.35)
    assert tme.H100.native_ridge == pytest.approx(10.1, abs=0.1)
    for spec in tme.CHIPS.values():
        assert spec.native_ridge == pytest.approx(
            spec.fp64_vector / spec.hbm_tbps)


def test_default_chip_env_selection(monkeypatch):
    monkeypatch.delenv(tme.CHIP_VAR, raising=False)
    assert tme.default_chip().name == "TPUv5e"
    monkeypatch.setenv(tme.CHIP_VAR, "H100")
    assert tme.default_chip() is tme.H100
    monkeypatch.setenv(tme.CHIP_VAR, "Z9000")
    with pytest.raises(ValueError, match="REPRO_TME_CHIP"):
        tme.default_chip()


def test_op_costs_per_kind():
    assert tme.op_costs("gemm", (4, 5, 6)) == (240.0, 8.0 * (20 + 30 + 24),
                                               24.0)
    assert tme.op_costs("gemv", (4, 5, 1)) == (40.0, 8.0 * (20 + 5 + 4), 4.0)
    W, Q, n_out = tme.op_costs("spmv_bell", (8, 4, 16))
    assert (W, n_out) == (64.0, 8.0)
    assert Q == 8 * 4 * 8 + 8 * 4 * 4 + 16 * 8 + 8 * 8
    W, Q, n_out = tme.op_costs("stencil7", (2, 3, 4))
    assert (W, Q, n_out) == (14.0 * 24, 16.0 * 24, 24.0)
    assert tme.op_costs("reduce", (100,)) == (200.0, 1600.0, 1.0)
    # attention (B, S, D, T): QK^T + PV flops, q/k/v/out f64 traffic.
    W, Q, n_out = tme.op_costs("attention", (2, 12, 16, 12))
    assert W == 4.0 * 2 * 12 * 12 * 16
    assert Q == 8.0 * 2 * (2 * 12 * 16 + 2 * 12 * 16)
    assert n_out == 2 * 12 * (12 + 16)
    # 3-tuple (S, D, T) means batch 1 (the dispatch entry always passes B).
    assert tme.op_costs("attention", (12, 16, 12)) == \
        tme.op_costs("attention", (1, 12, 16, 12))
    with pytest.raises(ValueError):
        tme.op_costs("fft", (8,))


def test_predict_op_time_route_beta_ordering():
    """xla (unfused, β = r) must predict ≥ pallas (fused, β = 1) for the same
    op on a memory-ridge-bound chip, and both must be positive and finite."""
    dims = (128, 256, 128)
    t_xla = tme.predict_op_time("gemm", dims, r=15, route="xla",
                                spec=tme.TPU_V5E)
    t_pal = tme.predict_op_time("gemm", dims, r=15, route="pallas",
                                spec=tme.TPU_V5E)
    assert 0.0 < t_pal < t_xla


def test_attention_emulated_time_routes_and_orders():
    """The fused kind's prediction: the xla route pays the materialised S/P
    matrices (β = r reference GEMMs), the pallas route streams them through
    the online-softmax scan (β = 1) — so xla ≥ pallas, and predict_op_time
    delegates to attention_emulated_time for kind="attention"."""
    dims = (1, 64, 32, 64)
    t_xla = tme.attention_emulated_time(dims, r=15, route="xla",
                                        spec=tme.TPU_V5E)
    t_pal = tme.attention_emulated_time(dims, r=15, route="pallas",
                                        spec=tme.TPU_V5E)
    assert 0.0 < t_pal < t_xla
    assert tme.predict_op_time("attention", dims, r=15, route="xla",
                               spec=tme.TPU_V5E) == pytest.approx(t_xla)
    assert tme.predict_op_time("attention", dims, r=15, route="pallas",
                               spec=tme.TPU_V5E) == pytest.approx(t_pal)


def test_predict_op_time_reduce_has_no_garner_term():
    """reduce is the §7.1(a) EFT path: no emulation, so prediction scales
    linearly in n (γ = 0 — no per-output reconstruction offset)."""
    t1 = tme.predict_op_time("reduce", (1 << 12,), spec=tme.TPU_V5E)
    t2 = tme.predict_op_time("reduce", (1 << 13,), spec=tme.TPU_V5E)
    assert t2 == pytest.approx(2 * t1, rel=1e-6)
