"""Perf-trajectory tooling: BENCH_*.json writer + baseline comparison.

``benchmarks`` is not an installed package; the repo root joins sys.path so
the CI lane (which runs pytest from the repo root anyway) and local runs both
resolve it.  ``check_regression`` is dependency-free by design — these tests
never touch jax.
"""

import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from benchmarks import check_regression  # noqa: E402


def _write(path: Path, payload) -> str:
    path.write_text(json.dumps(payload))
    return str(path)


def test_section_of_parses_and_rejects():
    assert check_regression.section_of("BENCH_dispatch.json") == "dispatch"
    assert check_regression.section_of("/tmp/x/BENCH_table1.json") == "table1"
    with pytest.raises(ValueError):
        check_regression.section_of("benchmark-smoke.csv")


def test_compare_flags_only_regressions_beyond_threshold():
    baseline = {"kernels": {"a/us": 100.0, "b/us": 100.0, "gone/us": 5.0}}
    current = {"a/us": 150.0, "b/us": 201.0, "new/us": 7.0}
    out = list(check_regression.compare("kernels", current, baseline, 2.0))
    warnings = [m for k, m in out if k == "warning"]
    notices = [m for k, m in out if k == "notice"]
    assert len(warnings) == 1 and "b/us" in warnings[0]      # 2.01x > 2x
    assert any("new/us" in n for n in notices)               # new row noticed
    assert any("gone/us" in n for n in notices)              # dropped row too


def test_compare_unknown_section_is_notice_not_warning():
    out = list(check_regression.compare("mystery", {"x/us": 1.0}, {}, 2.0))
    assert [k for k, _ in out] == ["notice"]


def test_main_new_rows_annotate_as_notice_and_exit_zero(tmp_path, capsys):
    """First CI run of a new section: baseline has no rows for it — the run
    must neither crash nor warn, only ::notice:: (even under --strict)."""
    base = _write(tmp_path / "baseline.json", {"dispatch": {"r/us": 10.0}})
    cur = _write(tmp_path / "BENCH_reductions.json", {"dot/us": 5.0})
    rc = check_regression.main([cur, "--baseline", base, "--strict"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "::notice" in out and "::warning" not in out


def test_main_warns_but_exits_zero(tmp_path, capsys):
    """The CI contract: >2x regressions annotate, never fail the build."""
    base = _write(tmp_path / "baseline.json", {"dispatch": {"r/us": 10.0}})
    cur = _write(tmp_path / "BENCH_dispatch.json", {"r/us": 25.0})
    rc = check_regression.main([cur, "--baseline", base])
    out = capsys.readouterr().out
    assert rc == 0
    assert "::warning" in out and "2.50x" in out
    # --strict flips the same comparison to a failure (local use).
    assert check_regression.main([cur, "--baseline", base, "--strict"]) == 1


def test_main_write_baseline_round_trips(tmp_path):
    cur = _write(tmp_path / "BENCH_spectral.json", {"fft/us": 12.5})
    base = tmp_path / "baseline.json"
    rc = check_regression.main([cur, "--baseline", str(base),
                                "--write-baseline"])
    assert rc == 0
    assert json.loads(base.read_text()) == {"spectral": {"fft/us": 12.5}}
    # a fresh run against the just-written baseline is clean even with --strict
    assert check_regression.main([cur, "--baseline", str(base),
                                  "--strict"]) == 0


def test_write_baseline_merges_sections(tmp_path):
    """A partial --section run refreshes only its own sections; the rest of
    the committed baseline survives."""
    base = _write(tmp_path / "baseline.json",
                  {"kernels": {"k/us": 3.0}, "spectral": {"fft/us": 9.0}})
    cur = _write(tmp_path / "BENCH_spectral.json", {"fft/us": 12.5})
    assert check_regression.main([cur, "--baseline", base,
                                  "--write-baseline"]) == 0
    assert json.loads(Path(base).read_text()) == {
        "kernels": {"k/us": 3.0}, "spectral": {"fft/us": 12.5}}


def test_committed_baseline_covers_ci_smoke_sections():
    """benchmarks/baseline.json (the committed trajectory anchor) must have
    rows for every section the CI fast lane runs with --json."""
    baseline = json.loads((REPO_ROOT / "benchmarks" / "baseline.json").read_text())
    for section in ("table1", "dispatch", "spectral", "kernels", "reductions",
                    "telemetry"):
        assert section in baseline, f"baseline missing section {section}"
    # table1 is derived-only (model rows, us == 0) and legitimately empty;
    # the empirical sections must carry timing rows.
    for section in ("dispatch", "spectral", "kernels", "reductions"):
        assert baseline[section], f"baseline section {section} has no rows"
    # route rows of the new seam kinds are part of the trajectory
    assert "kernel_spmv/route_pallas/us" in baseline["kernels"]
    assert "kernel_stencil/route_pallas/us" in baseline["kernels"]
    # the blocked-EFT reduction rows anchor the BLAS-1 trajectory
    assert "reductions/dot_blocked_n4096/us" in baseline["reductions"]


def test_run_json_writer_skips_derived_only_rows(tmp_path):
    """benchmarks.run.write_json: name -> us map, derived-only rows dropped.

    Imported in a subprocess: importing benchmarks.run flips jax x64 config,
    which must not leak into this pytest process.
    """
    code = (
        "import json\n"
        "from benchmarks.run import write_json\n"
        "rows = [('k/f64/beta', 12.34, 1.0), ('k/model', 0.0, 3.0)]\n"
        f"p = write_json('kernels', rows, {str(tmp_path)!r})\n"
        "print(json.dumps(json.load(open(p))))\n"
    )
    out = subprocess.run([sys.executable, "-c", code], cwd=REPO_ROOT,
                         capture_output=True, text=True, check=True)
    assert json.loads(out.stdout.strip()) == {"k/f64/beta": 12.34}
    assert (tmp_path / "BENCH_kernels.json").exists()


def test_run_json_writer_self_describing_rows(tmp_path):
    """5-tuple rows (route/shape_class provenance) serialise as objects; bare
    3-tuple rows stay plain floats — both in the same section."""
    code = (
        "import json\n"
        "from benchmarks.run import write_json\n"
        "rows = [('d/route_xla/us', 9.5, 1.0, 'xla', '128x256x128'),\n"
        "        ('d/plain/us', 3.25, 0.0)]\n"
        f"p = write_json('dispatch', rows, {str(tmp_path)!r})\n"
        "print(json.dumps(json.load(open(p))))\n"
    )
    out = subprocess.run([sys.executable, "-c", code], cwd=REPO_ROOT,
                         capture_output=True, text=True, check=True)
    assert json.loads(out.stdout.strip()) == {
        "d/route_xla/us": {"us": 9.5, "route": "xla",
                           "shape_class": "128x256x128"},
        "d/plain/us": 3.25,
    }


# --- self-describing rows through compare / write-baseline -------------------

def test_us_accepts_float_and_object_rows():
    assert check_regression._us(12.5) == 12.5
    assert check_regression._us({"us": 7.0, "route": "xla"}) == 7.0
    assert check_regression._us({}) == 0.0


def test_compare_handles_object_rows():
    baseline = {"telemetry": {"t/gemm_xla/us": 100.0}}
    current = {"t/gemm_xla/us": {"us": 300.0, "route": "xla",
                                 "shape_class": "64x64x64"}}
    out = list(check_regression.compare("telemetry", current, baseline, 2.0))
    assert [k for k, _ in out] == ["warning"]
    assert "3.00x" in out[0][1]


def test_write_baseline_normalises_object_rows(tmp_path):
    run = _write(tmp_path / "BENCH_telemetry.json",
                 {"t/a/us": {"us": 5.5, "route": "pallas",
                             "shape_class": "8x8x8"},
                  "t/b/us": 2.0})
    baseline = tmp_path / "baseline.json"
    assert check_regression.main(
        [run, "--baseline", str(baseline), "--write-baseline"]) == 0
    written = json.loads(baseline.read_text())
    assert written == {"telemetry": {"t/a/us": 5.5, "t/b/us": 2.0}}


# --- telemetry measured-vs-TME audit -----------------------------------------

def _telemetry_snapshot(ratio: float) -> dict:
    return {"chip": "TPUv5e",
            "counters": [
                {"kind": "gemm", "shape_class": "64x64x64", "route": "xla",
                 "calls": 3, "us": 100.0 * ratio, "tme_us": 100.0},
                {"kind": "solver.cg", "shape_class": "64", "route": "",
                 "calls": 5, "us": 40.0, "tme_us": 0.0},   # no prediction
            ]}


def test_audit_telemetry_flags_only_beyond_threshold():
    over = list(check_regression.audit_telemetry(_telemetry_snapshot(50.0),
                                                 10.0))
    assert len(over) == 1
    assert "gemm/xla" in over[0] and "50.0x" in over[0]
    assert "solver.cg" not in " ".join(over)   # prediction-free kinds skipped
    under = list(check_regression.audit_telemetry(_telemetry_snapshot(5.0),
                                                  10.0))
    assert under == []


def test_main_telemetry_notices_and_env_threshold(tmp_path, capsys,
                                                  monkeypatch):
    run = _write(tmp_path / "BENCH_telemetry.json", {"t/gemm_xla/us": 1.0})
    base = _write(tmp_path / "baseline.json", {"telemetry":
                                               {"t/gemm_xla/us": 1.0}})
    snap = _write(tmp_path / "telemetry.json", _telemetry_snapshot(50.0))

    assert check_regression.main(
        [run, "--baseline", base, "--telemetry", snap]) == 0
    out = capsys.readouterr().out
    assert "::notice title=TME model error::" in out
    assert "gemm/xla" in out and "> 10x" in out

    # env-overridable threshold: 100x silences the 50x ratio
    monkeypatch.setenv(check_regression.NOTICE_RATIO_VAR, "100")
    assert check_regression.main(
        [run, "--baseline", base, "--telemetry", snap]) == 0
    assert "TME model error" not in capsys.readouterr().out
