import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ozaki1

U64 = 2.0 ** -53
RNG = np.random.default_rng(11)


def test_slice_width_eq3():
    # Paper eq. (3): b* = (w_acc - ceil(log2 k)) / 2, clipped to input payload.
    assert ozaki1.slice_width(256, w_acc=31, input_bits=99) == 11
    assert ozaki1.slice_width(1024, w_acc=31, input_bits=99) == 10
    assert ozaki1.slice_width(4096, w_acc=31, input_bits=7) == 7  # input-bound
    assert ozaki1.slice_width(4096, w_acc=24, input_bits=11) == 6  # fp16 acc-bound


def test_decompose_recomposes_exactly():
    """Slice decomposition is an error-free transformation of the scaled integer."""
    from repro.core import splitting
    k = 128
    x = jnp.asarray(RNG.standard_normal((8, k)))
    plan = ozaki1.make_plan(k)
    slices, shift = ozaki1.slice_decompose(x, plan, scale_axis=-1)
    xi, shift2 = splitting.scale_to_int(x, plan.payload_bits, axis=-1)
    np.testing.assert_array_equal(np.asarray(shift), np.asarray(shift2))
    s, b = plan.num_slices, plan.slice_bits
    # exact integer recomposition (python ints — no float rounding in the check)
    sl = np.asarray(slices, np.int64)
    recon = np.zeros((8, k), dtype=object)
    for p in range(s):
        recon += sl[p].astype(object) * (2 ** ((s - 1 - p) * b))
    np.testing.assert_array_equal(recon.astype(np.float64), np.asarray(xi))


@pytest.mark.parametrize("k", [64, 512, 4096])
def test_accuracy(k):
    a = RNG.standard_normal((16, k))
    b = RNG.standard_normal((k, 12))
    c = np.asarray(ozaki1.emulated_matmul(jnp.asarray(a), jnp.asarray(b)))
    denom = np.abs(a) @ np.abs(b)
    assert np.max(np.abs(c - a @ b) / denom) <= 16 * U64


def test_quadratic_gemm_count_vs_ozaki2_linear():
    """The paper's headline structural contrast: Θ(S²) vs Θ(r)."""
    from repro.core import ozaki2
    k = 4096
    p1 = ozaki1.make_plan(k)
    p2 = ozaki2.make_plan(k)
    assert p1.num_gemms == p1.num_slices ** 2
    assert p1.num_gemms > 3 * p2.r  # 64 vs 16
