import math

import pytest

from repro.core import moduli as M


def test_default_moduli_pairwise_coprime():
    assert M.check_pairwise_coprime(M.DEFAULT_MODULI)


def test_default_moduli_fit_int8_balanced():
    for m in M.DEFAULT_MODULI:
        assert m <= 256
        assert -(m // 2) >= -128 and (m - 1) // 2 <= 127


def test_modinv():
    for a, m in [(3, 7), (251, 256), (256, 251), (100, 199)]:
        assert (M.modinv(a, m) * a) % m == 1
    with pytest.raises(ValueError):
        M.modinv(4, 256)


def test_balanced_range():
    for m in (256, 251, 7):
        vals = [M.balanced(x, m) for x in range(-3 * m, 3 * m)]
        assert min(vals) == -(m // 2)
        assert max(vals) == (m - 1) // 2
        for x in range(-3 * m, 3 * m):
            assert (M.balanced(x, m) - x) % m == 0


def test_garner_constants_tables():
    gc = M.garner_constants(M.DEFAULT_MODULI[:5])
    r = gc.r
    pref = [1]
    for j in range(1, r):
        pref.append(pref[-1] * gc.moduli[j - 1])
    for j in range(r):
        assert (int(gc.inv_pref[j]) * pref[j]) % gc.moduli[j] == 1
        for l in range(r):
            assert int(gc.pref_mod[j, l]) == pref[j] % gc.moduli[l]
        assert gc.pref_f64[j] == float(pref[j])
    assert gc.prod == pref[-1] * gc.moduli[-1]


def test_required_r_matches_paper_range():
    # Paper §2.3: published INT8 parameter sets use r ∈ [13, 16] for FP64.
    for k in (256, 1024, 4096, 16384):
        r = M.required_r(k, payload_bits=53)
        assert 13 <= r <= 16, (k, r)


def test_required_r_monotone_in_k_and_bits():
    rs = [M.required_r(k, 53) for k in (64, 1024, 16384, 262144)]
    assert rs == sorted(rs)
    assert M.required_r(1024, 24) < M.required_r(1024, 53)


def test_max_payload_bits_inverse_of_required_r():
    for k in (256, 4096):
        r = M.required_r(k, 53)
        assert M.max_payload_bits(r, k) >= 53
        assert M.max_payload_bits(r - 1, k) < 53


def test_capacity_bits():
    got = M.capacity_bits((256, 251))
    assert got == pytest.approx(8 + math.log2(251))
