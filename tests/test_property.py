"""Hypothesis property tests on the system's numeric invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: pip install -e .[test]")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import moduli as M
from repro.core import ozaki2, splitting

U64 = 2.0 ** -53


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(2, 24), k=st.integers(2, 160), n=st.integers(2, 24),
    scale_exp=st.integers(-40, 40), seed=st.integers(0, 2 ** 31 - 1),
    substrate=st.sampled_from(["int8", "fp8"]),
)
def test_ozaki2_error_bound_property(m, k, n, scale_exp, seed, substrate):
    """For any shape/scale, emulated GEMM error stays within the §2.5 band."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)) * 2.0 ** scale_exp
    b = rng.standard_normal((k, n)) * 2.0 ** -scale_exp
    plan = ozaki2.make_plan(k, substrate=substrate)
    c = np.asarray(ozaki2.emulated_matmul(jnp.asarray(a), jnp.asarray(b), plan))
    denom = np.abs(a) @ np.abs(b) + 1e-300
    assert np.max(np.abs(c - a @ b) / denom) <= 32 * U64


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(-(2 ** 100), 2 ** 100), min_size=1, max_size=32))
def test_garner_bigint_roundtrip_property(vals):
    """CRT decompose -> balanced Garner reconstructs any |C| < M/4 exactly."""
    plan = ozaki2.Plan(moduli=M.DEFAULT_MODULI, payload_bits=53)
    Mprod = plan.garner.prod
    vals = [v % (Mprod // 4) - Mprod // 8 for v in vals]
    cres = np.stack([
        np.array([M.balanced(v, mod) for v in vals], np.int32)
        for mod in plan.moduli
    ])
    got = np.asarray(ozaki2.garner_reconstruct(jnp.asarray(cres), plan))
    want = np.array([float(v) for v in vals])
    # float64 rounding of the exact integer is the only allowed deviation
    np.testing.assert_allclose(got, want, rtol=8 * U64)


@settings(max_examples=50, deadline=None)
@given(st.integers(-(2 ** 52), 2 ** 52))
def test_hilo_residues_property(x):
    xi = jnp.asarray([float(x)])
    hi, lo = splitting.split_hi_lo(xi)
    assert int(hi[0]) * M.SPLIT_RADIX + int(lo[0]) == x
    res = splitting.residues_from_hilo(hi, lo, M.DEFAULT_MODULI)
    for i, mod in enumerate(M.DEFAULT_MODULI):
        assert (int(res[i, 0]) - x) % mod == 0
        assert -(mod // 2) <= int(res[i, 0]) <= (mod - 1) // 2


@settings(max_examples=20, deadline=None)
@given(
    k=st.integers(2, 500), payload=st.integers(8, 53),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_scaling_fills_payload_property(k, payload, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((4, k)))
    xi, shift = splitting.scale_to_int(x, payload, axis=-1)
    assert float(jnp.max(jnp.abs(xi))) < 2.0 ** payload
    assert float(jnp.max(jnp.abs(xi))) >= 2.0 ** (payload - 2)
