import jax.numpy as jnp
import numpy as np

from repro.core import splitting as S
from repro.core.moduli import DEFAULT_MODULI, SPLIT_RADIX


RNG = np.random.default_rng(42)


def _rand_ints(shape, bits):
    lim = 2 ** bits
    return RNG.integers(-lim + 1, lim, size=shape).astype(np.float64)


def test_split_hi_lo_exact_roundtrip():
    xi = jnp.asarray(_rand_ints((64, 64), 52))
    hi, lo = S.split_hi_lo(xi)
    assert hi.dtype == jnp.int32 and lo.dtype == jnp.int32
    back = S.merge_hi_lo(hi, lo)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(xi))
    # lo is balanced: |lo| <= 2^25
    assert np.abs(np.asarray(lo)).max() <= SPLIT_RADIX // 2


def test_residues_hilo_matches_int64_oracle():
    xi = jnp.asarray(_rand_ints((128,), 52))
    got = np.asarray(S.residues_from_hilo(*S.split_hi_lo(xi), DEFAULT_MODULI))
    want = np.asarray(S.residues_direct(xi, DEFAULT_MODULI))
    np.testing.assert_array_equal(got, want)


def test_residues_are_balanced_int8():
    xi = jnp.asarray(_rand_ints((256,), 52))
    res = np.asarray(S.residues_from_hilo(*S.split_hi_lo(xi), DEFAULT_MODULI))
    assert res.dtype == np.int8
    for i, m in enumerate(DEFAULT_MODULI):
        assert res[i].min() >= -(m // 2)
        assert res[i].max() <= (m - 1) // 2
        # residue congruent to the original value
        np.testing.assert_array_equal(
            np.mod(res[i].astype(object) - np.asarray(xi).astype(object), m), 0)


def test_scale_to_int_bounds_and_exactness():
    x = jnp.asarray(RNG.standard_normal((32, 100)) * 10.0 ** RNG.integers(-8, 8, (32, 1)))
    for p in (24, 53):
        xi, shift = S.scale_to_int(x, p, axis=-1)
        assert np.abs(np.asarray(xi)).max() < 2.0 ** p
        assert np.asarray(xi).max() >= 2.0 ** (p - 2)  # scaling actually fills payload
        # xi is integer valued
        np.testing.assert_array_equal(np.asarray(xi), np.round(np.asarray(xi)))
        # pow2 rescale recovers x to within the rounding of (4): the error is
        # *absolute* on the per-row integer grid, 0.5 * 2^-shift_i (App. C).
        back = np.asarray(xi) * 2.0 ** (-np.asarray(shift)[:, None].astype(np.float64))
        atol = 0.5 * 2.0 ** (-np.asarray(shift)[:, None].astype(np.float64))
        assert np.all(np.abs(back - np.asarray(x)) <= atol * (1 + 1e-12))


def test_scale_to_int_zero_rows():
    x = jnp.zeros((4, 8))
    xi, shift = S.scale_to_int(x, 53, axis=-1)
    assert np.all(np.asarray(xi) == 0)
    assert np.all(np.isfinite(np.asarray(shift)))


def test_apply_unscale_exact_pow2():
    c = jnp.asarray(RNG.standard_normal((8, 8)))
    sr = jnp.asarray(RNG.integers(-10, 10, 8), dtype=jnp.int32)
    sc = jnp.asarray(RNG.integers(-10, 10, 8), dtype=jnp.int32)
    out = np.asarray(S.apply_unscale(c, sr, sc))
    want = np.asarray(c) * 2.0 ** (-(np.asarray(sr)[:, None] + np.asarray(sc)[None, :]))
    np.testing.assert_array_equal(out, want)  # power-of-two scaling is exact
