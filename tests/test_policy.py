import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import Policy, POLICIES

U64 = 2.0 ** -53
RNG = np.random.default_rng(5)


@pytest.mark.parametrize("name", POLICIES)
def test_policy_dot_runs_and_shapes(name):
    x = jnp.asarray(RNG.standard_normal((4, 6, 32)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((32, 16)), jnp.float32)
    y = Policy(name).dot(x, w)
    assert y.shape == (4, 6, 16)
    assert y.dtype == x.dtype
    assert np.all(np.isfinite(np.asarray(y)))


def test_emulated_policies_match_fp64_oracle():
    x = jnp.asarray(RNG.standard_normal((8, 64)))
    w = jnp.asarray(RNG.standard_normal((64, 8)))
    want = np.asarray(x, np.float64) @ np.asarray(w, np.float64)
    denom = np.abs(np.asarray(x)) @ np.abs(np.asarray(w))
    for name in ("ozaki2_int8", "ozaki2_fp8", "ozaki1_int8"):
        got = np.asarray(Policy(name).dot(x, w))
        assert np.max(np.abs(got - want) / denom) <= 16 * U64, name


def test_bf16_policy_is_lower_precision():
    x = jnp.asarray(RNG.standard_normal((16, 128)))
    w = jnp.asarray(RNG.standard_normal((128, 16)))
    want = np.asarray(x) @ np.asarray(w)
    bf16_err = np.max(np.abs(np.asarray(Policy("bf16").dot(x, w)) - want))
    emu_err = np.max(np.abs(np.asarray(Policy("ozaki2_int8").dot(x, w)) - want))
    assert emu_err < bf16_err / 1e6  # emulation is FP64-grade; bf16 is ~8-bit


def test_emulated_grads_match_fp64_grads():
    """The custom VJP: gradient of emulated matmul == emulated matmul of gradient."""
    x = jnp.asarray(RNG.standard_normal((4, 32)))
    w = jnp.asarray(RNG.standard_normal((32, 4)))

    def loss(policy, xx, ww):
        return jnp.sum(policy.dot(xx, ww) ** 2)

    gx64, gw64 = jax.grad(lambda a, b: loss(Policy("fp64"), a, b), (0, 1))(x, w)
    gxe, gwe = jax.grad(lambda a, b: loss(Policy("ozaki2_int8"), a, b), (0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gxe), np.asarray(gx64), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(gwe), np.asarray(gw64), rtol=1e-12)


def test_policy_rejects_unknown():
    with pytest.raises(ValueError):
        Policy("fp16_emulated")


def test_policy_is_hashable_static():
    @jax.jit
    def f(x):
        return Policy("fp32").dot(x, jnp.eye(8, dtype=x.dtype))

    x = jnp.asarray(RNG.standard_normal((3, 8)), jnp.float32)
    np.testing.assert_allclose(np.asarray(f(x)), np.asarray(x), rtol=1e-6)
