import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import moduli as M
from repro.core import ozaki2

U64 = 2.0 ** -53
RNG = np.random.default_rng(7)


def _relerr(c, a, b):
    """Componentwise error relative to |A||B| (the §2.5 error measure)."""
    denom = np.abs(a) @ np.abs(b) + 1e-300
    return np.max(np.abs(c - a @ b) / denom)


@pytest.mark.parametrize("substrate", ["int8", "fp8"])
@pytest.mark.parametrize("mkn", [(8, 16, 8), (32, 64, 24), (17, 130, 9), (64, 1024, 32)])
def test_accuracy_well_conditioned(substrate, mkn):
    m, k, n = mkn
    a = RNG.standard_normal((m, k))
    b = RNG.standard_normal((k, n))
    plan = ozaki2.make_plan(k, substrate=substrate)
    c = np.asarray(ozaki2.emulated_matmul(jnp.asarray(a), jnp.asarray(b), plan))
    # Paper §2.5: observed error within 2–10 u for bounded condition numbers.
    assert _relerr(c, a, b) <= 16 * U64


@pytest.mark.parametrize("substrate", ["int8", "fp8"])
def test_accuracy_wide_dynamic_range(substrate):
    """App. C / [32]: error bounded by u|A||B| plus the Phase-1 quantisation term.

    For rows/cols with heterogeneous magnitudes the ⌊D A⌉ rounding of eq. (4)
    contributes E_A = 0.5·2^{-shift_A} per element; the a-priori componentwise bound
    is |C - AB| <= c₁·u·(|A||B|) + c₂·(E_A|B| + |A|E_B + k·E_A E_B).
    """
    k = 256
    a = RNG.standard_normal((16, k)) * np.exp(2 * RNG.standard_normal((16, k)))
    b = RNG.standard_normal((k, 12)) * np.exp(2 * RNG.standard_normal((k, 12)))
    plan = ozaki2.make_plan(k, substrate=substrate)
    c = np.asarray(ozaki2.emulated_matmul(jnp.asarray(a), jnp.asarray(b), plan))

    from repro.core import splitting
    _, sa = splitting.scale_to_int(jnp.asarray(a), plan.payload_bits, axis=-1)
    _, sb = splitting.scale_to_int(jnp.asarray(b), plan.payload_bits, axis=0)
    ea = 0.5 * 2.0 ** (-np.asarray(sa, np.float64))       # per-row abs rounding
    eb = 0.5 * 2.0 ** (-np.asarray(sb, np.float64))       # per-col abs rounding
    quant = (ea[:, None] * np.sum(np.abs(b), axis=0)[None, :]
             + np.sum(np.abs(a), axis=1)[:, None] * eb[None, :]
             + k * ea[:, None] * eb[None, :])
    bound = 8 * U64 * (np.abs(a) @ np.abs(b)) + 2.0 * quant
    assert np.all(np.abs(c - a @ b) <= bound)


def test_int8_and_fp8_substrates_bit_identical():
    """Both substrates compute the same exact modular products -> identical output."""
    k = 192
    a = jnp.asarray(RNG.standard_normal((24, k)))
    b = jnp.asarray(RNG.standard_normal((k, 16)))
    c_int8 = ozaki2.emulated_matmul(a, b, ozaki2.make_plan(k, substrate="int8"))
    c_fp8 = ozaki2.emulated_matmul(a, b, ozaki2.make_plan(k, substrate="fp8"))
    np.testing.assert_array_equal(np.asarray(c_int8), np.asarray(c_fp8))


def test_exact_on_small_integer_matrices():
    """CRT roundtrip: products of smallish integers are recovered EXACTLY."""
    k = 64
    a = jnp.asarray(RNG.integers(-1000, 1000, (16, k)).astype(np.float64))
    b = jnp.asarray(RNG.integers(-1000, 1000, (k, 8)).astype(np.float64))
    plan = ozaki2.make_plan(k)
    c = np.asarray(ozaki2.emulated_matmul(a, b, plan))
    want = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    np.testing.assert_array_equal(c, want)


def test_garner_against_python_bigint():
    """Vectorised balanced Garner == exact CRT with arbitrary-precision ints."""
    plan = ozaki2.make_plan(4096)  # r = 16
    gc = plan.garner
    vals = np.concatenate([
        RNG.integers(-(10 ** 15), 10 ** 15, 64),
        np.array([0, 1, -1, 2 ** 40, -(2 ** 40)]),
    ])
    # residues as the modular matmul would produce them (balanced)
    cres = np.stack([
        np.array([M.balanced(int(v), m) for v in vals], np.int32)
        for m in plan.moduli
    ])
    got = np.asarray(ozaki2.garner_reconstruct(jnp.asarray(cres), plan))
    np.testing.assert_array_equal(got, vals.astype(np.float64))


def test_modular_matmul_congruence():
    """C^(i) ≡ ÃB̃ (mod m_i) for every modulus, both substrates."""
    k = 128
    a = jnp.asarray(RNG.standard_normal((8, k)))
    b = jnp.asarray(RNG.standard_normal((k, 8)))
    for substrate in ("int8", "fp8"):
        plan = ozaki2.make_plan(k, substrate=substrate)
        ares, _ = ozaki2.decompose(a, plan, scale_axis=-1)
        bres, _ = ozaki2.decompose(b, plan, scale_axis=0)
        cres = np.asarray(ozaki2.modular_matmul(ares, bres, plan))
        ai = np.asarray(ares, np.int64)
        bi = np.asarray(bres, np.int64)
        for i, m in enumerate(plan.moduli):
            want = (ai[i] @ bi[i]) % m
            got = cres[i] % m
            np.testing.assert_array_equal(got, want)
            # balanced representatives
            assert cres[i].min() >= -(m // 2) and cres[i].max() <= (m - 1) // 2


def test_long_contraction_chunking():
    """k beyond the int32-safe chunk still gives FP64-grade accuracy."""
    k = (1 << 17) + 1024  # forces the chunked path
    a = RNG.standard_normal((4, k))
    b = RNG.standard_normal((k, 4))
    plan = ozaki2.make_plan(k)
    c = np.asarray(ozaki2.emulated_matmul(jnp.asarray(a), jnp.asarray(b), plan))
    assert _relerr(c, a, b) <= 64 * U64


def test_hilo_and_direct_paths_identical():
    k = 96
    a = jnp.asarray(RNG.standard_normal((8, k)))
    b = jnp.asarray(RNG.standard_normal((k, 8)))
    plan = ozaki2.make_plan(k)
    c1 = ozaki2.emulated_matmul(a, b, plan, via_hilo=True)
    c2 = ozaki2.emulated_matmul(a, b, plan, via_hilo=False)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_batched():
    a = jnp.asarray(RNG.standard_normal((3, 8, 32)))
    b = jnp.asarray(RNG.standard_normal((3, 32, 8)))
    plan = ozaki2.make_plan(32)
    c = np.asarray(ozaki2.emulated_matmul_batched(a, b, plan))
    want = np.einsum("bij,bjk->bik", np.asarray(a), np.asarray(b))
    denom = np.einsum("bij,bjk->bik", np.abs(np.asarray(a)), np.abs(np.asarray(b)))
    assert np.max(np.abs(c - want) / denom) <= 16 * U64


def test_reduced_r_degrades_gracefully():
    """§2.4 sensitivity: fewer moduli -> smaller payload -> larger (bounded) error."""
    k = 256
    a = jnp.asarray(RNG.standard_normal((16, k)))
    b = jnp.asarray(RNG.standard_normal((k, 16)))
    errs = []
    for r in (8, 10, 12, 14):
        plan = ozaki2.make_plan(k, r=r)
        c = np.asarray(ozaki2.emulated_matmul(a, b, plan))
        errs.append(_relerr(c, np.asarray(a), np.asarray(b)))
    assert errs == sorted(errs, reverse=True) or errs[-1] <= errs[0]
    assert errs[0] <= 2.0 ** -20  # r=8 still ~fp32-grade
    assert errs[-1] <= 16 * U64


def test_plan_alpha():
    p = ozaki2.make_plan(4096, substrate="int8")
    assert p.alpha == p.r
    p8 = ozaki2.make_plan(4096, substrate="fp8")
    assert p8.alpha == 3 * p8.r
