"""Serving demo: continuous batching over a reduced gemma3 (sliding-window KV).

    PYTHONPATH=src python examples/serve_demo.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.configs import registry
from repro.models.transformer import Model
from repro.serve.engine import ContinuousBatcher, Request, ServeEngine


def main():
    cfg = registry.get_config("gemma3-4b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=2, max_seq=64)
    batcher = ContinuousBatcher(engine)

    rng = np.random.default_rng(0)
    for uid in range(5):
        batcher.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
            max_new_tokens=6))
    done = batcher.run_to_completion(max_steps=200)
    for r in sorted(done, key=lambda r: r.uid):
        print(f"request {r.uid}: prompt={list(r.prompt)} -> {r.generated}")
    assert len(done) == 5
    print("PASS: 5 requests served through 2 slots with cache reuse.")


if __name__ == "__main__":
    main()
