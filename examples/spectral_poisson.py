"""Spectral subsystem demo: Ozaki-Bailey FFT + a direct Poisson solve.

Every multiplication below — the DFT GEMM passes of the four-step FFT, the
realified complex products — runs through ``repro.core.dispatch``, i.e. on the
emulated-FP64 Ozaki-II path the paper builds on the FP8/INT8 matrix unit.

    PYTHONPATH=src python examples/spectral_poisson.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro import spectral
from repro.core import tme
from repro.hpc import poisson


def main():
    rng = np.random.default_rng(0)

    # 1. The FFT dwarf: four-step transform vs the jnp.fft FP64 oracle.
    n = 1024
    x = jnp.asarray(rng.standard_normal(n) + 1j * rng.standard_normal(n))
    got = spectral.fft(x)
    rel = float(jnp.linalg.norm(got - jnp.fft.fft(x))
                / jnp.linalg.norm(jnp.fft.fft(x)))
    n1, n2 = spectral.choose_factors(n)
    print(f"fft n={n} (four-step {n1}x{n2}): rel err vs jnp.fft = {rel:.2e}")
    assert rel <= 1e-12

    # 2. Composite solver layer: direct spectral Poisson solve.
    f, u_exact = poisson.manufactured_rhs((48, 48), seed=1)
    res = poisson.poisson_solve_checked(f)
    err = float(jnp.max(jnp.abs(res.u - u_exact)))
    print(f"poisson 48x48: true residual {res.residual:.2e}, "
          f"max deviation from manufactured u: {err:.2e}")
    assert res.residual <= 1e-12

    # 3. TME projection: emulated-over-native FFT on a post-FP64 chip.
    import dataclasses
    for chip in ("H100", "B300"):
        spec = tme.CHIPS[chip]
        params = dataclasses.replace(
            tme.EmulationParams.ozaki2(r=10, substrate="fp8"),
            gamma=tme.garner_gamma(spec, 10))
        nat = tme.fft_native_time(1 << 18, spec, batch=4096)
        emu = tme.fft_emulated_time(1 << 18, spec, params, batch=4096)
        print(f"TME n=2^18 batch=4096 on {chip}: native {nat*1e3:.2f} ms, "
              f"emulated {emu*1e3:.2f} ms, speedup {nat/emu:.2f}x")

    print("PASS: spectral transforms inherit the dispatch-layer contract.")


if __name__ == "__main__":
    main()
