"""Iterative-solver demo (paper §7.1(a)): CG on a 2-D Laplacian where the SpMV
runs through the fused Ozaki-II Blocked-ELL Pallas kernel and the reductions use
FP32+Kahan-style compensation — the post-FP64 stack for sparse linear algebra.

    PYTHONPATH=src python examples/cg_solver.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.hpc import spmv_formats
from repro.hpc.cg import cg_solve, cg_solve_bell


def main():
    nx = ny = 12
    dense = spmv_formats.laplacian_2d(nx, ny)
    val, col = spmv_formats.to_blocked_ell(dense, bw=8)
    rho = spmv_formats.padding_ratio(val)
    print(f"2-D Laplacian {nx}x{ny}: {dense.shape[0]} unknowns, "
          f"Blocked-ELL bw=8, rho_pad={rho:.2f} (Appendix D beta bound)")

    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(dense.shape[0]))

    # Native float64 CG (the oracle)
    ref = cg_solve(lambda x: jnp.asarray(dense) @ x, b, tol=1e-11)
    # Ozaki-II emulated SpMV CG (the post-FP64 path)
    emu = cg_solve_bell(jnp.asarray(val), jnp.asarray(col), b, tol=1e-11)

    print(f"native f64 CG : {ref.iters} iters, residual {ref.residual:.2e}")
    print(f"ozaki-II   CG : {emu.iters} iters, residual {emu.residual:.2e}")
    dx = float(jnp.max(jnp.abs(ref.x - emu.x)) / jnp.max(jnp.abs(ref.x)))
    print(f"solution deviation: {dx:.2e}")
    assert emu.converged and emu.iters <= ref.iters + 2
    print("PASS: emulated SpMV preserves CG convergence.")


if __name__ == "__main__":
    main()
