"""FP64-exact training on FP64-free hardware — the paper's thesis, end to end.

Trains the same tiny model twice: once with every weight matmul in native XLA
float64 (the oracle — impossible on a B300/TPU at speed), once with every weight
matmul routed through Ozaki-II on the int8 substrate (the paper's replacement).
The two loss trajectories agree to ~1e-12 relative: the emulated path IS double
precision for training purposes.

    PYTHONPATH=src python examples/fp64_exact_training.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models.transformer import Model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.loop import make_train_step
from repro.data.pipeline import DataConfig, synth_batch


def run(policy_name: str, steps: int = 8):
    cfg = registry.get_config("yi-6b", smoke=True, policy_name=policy_name,
                              compute_dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    dc = DataConfig(global_batch=4, seq_len=32)
    losses = []
    for i in range(steps):
        batch = synth_batch(dc, cfg, i)
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    return np.asarray(losses)


def main():
    fp64 = run("fp64")
    emulated = run("ozaki2_int8")
    bf16 = run("bf16")
    print(f"{'step':>4} {'fp64 (oracle)':>16} {'ozaki2_int8':>16} {'bf16':>12}")
    for i, (a, b, c) in enumerate(zip(fp64, emulated, bf16)):
        print(f"{i:4d} {a:16.10f} {b:16.10f} {c:12.6f}")
    dev = np.max(np.abs(fp64 - emulated) / np.abs(fp64))
    dev_bf16 = np.max(np.abs(fp64 - bf16) / np.abs(fp64))
    print(f"\nmax relative loss deviation: ozaki2_int8 = {dev:.2e} "
          f"(bf16 = {dev_bf16:.2e})")
    assert dev < 1e-9, "emulated training diverged from the float64 oracle"
    print("PASS: Ozaki-II training is float64-equivalent.")


if __name__ == "__main__":
    main()
