"""The paper's three memory-bound kernels (Algorithms 1-3), fused, validated.

    PYTHONPATH=src python examples/hpc_kernels_demo.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import tme
from repro.kernels import ops, ref


def main():
    rng = np.random.default_rng(0)
    u64 = 2.0 ** -53

    # Algorithm 1: batched GEMV (B=8) — the ~24x B300 win of Table 3
    A = jnp.asarray(rng.standard_normal((512, 256)))
    X = jnp.asarray(rng.standard_normal((256, 8)))
    y = ops.ozaki_gemv(A, X)
    err = float(jnp.max(jnp.abs(y - ref.gemv_f64(A, X)))
                / jnp.max(jnp.abs(A) @ jnp.abs(X)))
    print(f"bGEMV  (B=8): err={err/u64:.2f}u | projected B300 speedup "
          f"{tme.speedup(4.0, tme.B300, tme.EmulationParams.ozaki2()):.1f}x")

    # Algorithm 2: 7-point stencil
    u = jnp.asarray(rng.standard_normal((24, 24, 24)))
    c = jnp.asarray(np.array([6.0, -1, -1, -1, -1, -1, -1]))
    v = ops.ozaki_stencil7(u, c)
    verr = float(jnp.max(jnp.abs(v - ref.stencil7_f64(u, c))))
    print(f"stencil 7pt : abs err={verr:.2e} | projected B300 speedup "
          f"{tme.speedup(0.5, tme.B300, tme.EmulationParams.ozaki2()):.1f}x")

    # Algorithm 3: Blocked-ELL SpMV
    M, N, bw = 1024, 1024, 8
    col = jnp.asarray(rng.integers(0, N, (M, bw)).astype(np.int32))
    val = jnp.asarray(rng.standard_normal((M, bw)))
    x = jnp.asarray(rng.standard_normal(N))
    yv = ops.ozaki_spmv_bell(val, col, x)
    serr = float(jnp.max(jnp.abs(yv - ref.spmv_bell_f64(val, col, x))))
    print(f"SpMV (BELL) : abs err={serr:.2e} | projected B300 speedup "
          f"{tme.speedup(0.2, tme.B300, tme.EmulationParams.ozaki2()):.2f}x")
    print("PASS: all three fused kernels at FP64-equivalent accuracy.")


if __name__ == "__main__":
    main()
