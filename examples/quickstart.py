"""Quickstart: emulated-FP64 matmul + one training step, in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import ozaki2
from repro.configs import registry
from repro.models.transformer import Model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.loop import make_train_step


def main():
    # 1. The paper's contribution: FP64-accurate GEMM on an int8/fp8 substrate.
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((256, 512)))
    b = jnp.asarray(rng.standard_normal((512, 128)))
    plan = ozaki2.make_plan(512, substrate="int8")
    c_emulated = ozaki2.emulated_matmul(a, b, plan)
    c_native = jnp.dot(a, b)
    err = float(jnp.max(jnp.abs(c_emulated - c_native))
                / jnp.max(jnp.abs(c_native)))
    print(f"Ozaki-II (r={plan.r} moduli, int8 substrate): "
          f"max rel deviation from native float64 = {err:.2e}")

    # 2. The same arithmetic as a precision policy inside an LM training step.
    cfg = registry.get_config("yi-6b", smoke=True, policy_name="bf16")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    batch = registry.concrete_batch(
        cfg, registry.SHAPES_BY_NAME["train_4k"], batch=4, seq=32)
    for i in range(5):
        params, opt, metrics = step(params, opt, batch)
        print(f"step {i}: loss={float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
