"""Model-level benchmarks: smoke-config step timings per architecture.

CPU wall-clock for the reduced configs (machinery check — TPU perf lives in the
dry-run roofline).  One row per (arch, step-kind).
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models.transformer import Model
from repro.optim.adamw import adamw_init
from repro.train.loop import make_train_step

Row = Tuple[str, float, float]


def smoke_step_timings() -> List[Row]:
    rows: List[Row] = []
    for arch in registry.list_archs():
        cfg = registry.get_config(arch, smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        n_params = sum(int(x.size) for x in jax.tree.leaves(params))
        batch = registry.concrete_batch(
            cfg, registry.SHAPES_BY_NAME["train_4k"], batch=2, seq=16)

        step = jax.jit(make_train_step(model))
        opt = adamw_init(params)
        p, o, m = step(params, opt, batch)          # compile
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(3):
            p, o, m = step(p, o, batch)
        jax.block_until_ready(m["loss"])
        us = (time.perf_counter() - t0) / 3 * 1e6
        rows.append((f"model_train_step/{arch}", us, float(n_params)))

        cache = model.init_cache(batch=2, seq_len=32)
        dec = jax.jit(model.decode_step)
        lg, cache = dec(params, cache, jnp.zeros((2, 1), jnp.int32),
                        jnp.asarray(0, jnp.int32))
        jax.block_until_ready(lg)
        t0 = time.perf_counter()
        for i in range(5):
            lg, cache = dec(params, cache, jnp.zeros((2, 1), jnp.int32),
                            jnp.asarray(i + 1, jnp.int32))
        jax.block_until_ready(lg)
        us = (time.perf_counter() - t0) / 5 * 1e6
        rows.append((f"model_decode_step/{arch}", us, float(n_params)))
    return rows
