"""Benchmark harness — one section per paper table + empirical validations.

Prints ``name,us_per_call,derived`` CSV (one row per measured/derived quantity).
Run: ``PYTHONPATH=src python -m benchmarks.run [--section NAME]``.

x64 is enabled (before JAX initialises) because the emulation benchmarks compare
against float64 oracles; device count stays 1 (the dry-run owns the 512-device
configuration, see src/repro/launch/dryrun.py).
"""

import argparse
import sys

import jax

jax.config.update("jax_enable_x64", True)


def _sections():
    # Imports deferred so --section only pays for what it runs.
    from benchmarks import accuracy, tables

    from benchmarks import dispatch as dispatch_bench

    secs = {
        "dispatch": dispatch_bench.dispatch_paths,
        "table1": tables.table1_slice_counts,
        "table2": tables.table2_architectures,
        "table3": tables.table3_speedups,
        "table4": tables.table4_h100_baseline,
        "table5": tables.table5_substrates,
        "moduli": tables.moduli_requirements,
        "error_vs_r": accuracy.error_vs_r,
        "volume": accuracy.ozaki1_vs_ozaki2_volume,
        "wallclock": accuracy.emulation_wallclock,
    }
    try:
        from benchmarks import kernels as kernel_bench
        secs["kernels"] = kernel_bench.all_kernels
    except ImportError:
        pass
    try:
        from benchmarks import models as model_bench
        secs["models"] = model_bench.smoke_step_timings
    except ImportError:
        pass
    return secs


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--section", default=None,
                        help="run a single section (default: all)")
    args = parser.parse_args()

    secs = _sections()
    names = [args.section] if args.section else list(secs)
    print("name,us_per_call,derived")
    ok = True
    for name in names:
        try:
            for row, us, derived in secs[name]():
                print(f"{row},{us:.2f},{derived:.6g}")
        except Exception as e:  # pragma: no cover - surfacing, not hiding
            ok = False
            print(f"{name}/ERROR,0,0  # {type(e).__name__}: {e}", file=sys.stderr)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
