"""Benchmark harness — one section per paper table + empirical validations.

Prints ``name,us_per_call,derived,route,shape_class`` CSV (one row per
measured/derived quantity; route/shape_class blank for rows the telemetry
layer didn't observe).
Run: ``PYTHONPATH=src python -m benchmarks.run [--section NAME] [--json [DIR]]``.

``--json`` additionally writes one ``BENCH_<section>.json`` file per section
into DIR (default: the current directory) — the machine-readable
perf-trajectory artifact CI uploads and feeds to
``benchmarks.check_regression`` against the committed
``benchmarks/baseline.json``.  Rows with telemetry-sourced provenance are
self-describing objects ``{"us":…, "route":…, "shape_class":…}``; plain rows
stay bare floats (both forms are accepted downstream).

x64 is enabled (before JAX initialises) because the emulation benchmarks compare
against float64 oracles; device count stays 1 (the dry-run owns the 512-device
configuration, see src/repro/launch/dryrun.py).
"""

import argparse
import json
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)


def _section(module: str, attr: str):
    # Import deferred into the thunk so --section only pays for what it runs.
    def run():
        import importlib
        return getattr(importlib.import_module(f"benchmarks.{module}"), attr)()
    return run


def _sections():
    return {
        "dispatch": _section("dispatch", "dispatch_paths"),
        "spectral": _section("spectral", "spectral_section"),
        "table1": _section("tables", "table1_slice_counts"),
        "table2": _section("tables", "table2_architectures"),
        "table3": _section("tables", "table3_speedups"),
        "table4": _section("tables", "table4_h100_baseline"),
        "table5": _section("tables", "table5_substrates"),
        "moduli": _section("tables", "moduli_requirements"),
        "error_vs_r": _section("accuracy", "error_vs_r"),
        "volume": _section("accuracy", "ozaki1_vs_ozaki2_volume"),
        "wallclock": _section("accuracy", "emulation_wallclock"),
        # An import failure here surfaces as the section's ERROR row (exit 1)
        # rather than the section silently vanishing from the registry.
        "kernels": _section("kernels", "all_kernels"),
        "attention": _section("attention", "attention_section"),
        "reductions": _section("reductions", "reductions_section"),
        "models": _section("models", "smoke_step_timings"),
        "telemetry": _section("telemetry", "telemetry_section"),
    }


def _normalize(row):
    """Accept legacy 3-tuples and telemetry-aware 5-tuples uniformly.

    Returns (name, us, derived, route, shape_class) with route/shape_class ""
    for rows that carry no provenance.
    """
    if len(row) == 3:
        name, us, derived = row
        return name, us, derived, "", ""
    name, us, derived, route, shape_class = row
    return name, us, derived, route or "", shape_class or ""


def write_json(section: str, rows, out_dir: str) -> str:
    """Write BENCH_<section>.json (row name -> timing) and return its path.

    Derived-only rows (us == 0: model projections, structural bounds) are
    timing-free and excluded — the JSON is the perf trajectory, not the table.
    Rows with telemetry provenance serialise as ``{"us":…, "route":…,
    "shape_class":…}`` so the artifact is self-describing; bare rows stay
    plain floats for baseline compatibility.
    """
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{section}.json")
    payload = {}
    for row in rows:
        name, us, _, route, shape_class = _normalize(row)
        if us <= 0.0:
            continue
        if route or shape_class:
            payload[name] = {"us": round(us, 2), "route": route,
                             "shape_class": shape_class}
        else:
            payload[name] = round(us, 2)
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--section", default=None,
                        help="comma-separated section name(s) (default: all)")
    parser.add_argument("--json", nargs="?", const=".", default=None,
                        metavar="DIR",
                        help="also write BENCH_<section>.json (name -> "
                             "us_per_call) into DIR (default: cwd)")
    args = parser.parse_args()

    secs = _sections()
    if args.section:
        names = [s.strip() for s in args.section.split(",") if s.strip()]
        unknown = [s for s in names if s not in secs]
        if unknown:
            parser.error(f"unknown section(s) {unknown}; "
                         f"available: {', '.join(secs)}")
    else:
        names = list(secs)
    print("name,us_per_call,derived,route,shape_class")
    ok = True
    for name in names:
        try:
            rows = list(secs[name]())
        except Exception as e:  # pragma: no cover - surfacing, not hiding
            ok = False
            print(f"{name}/ERROR,0,0  # {type(e).__name__}: {e}", file=sys.stderr)
            continue
        for row in rows:
            rname, us, derived, route, shape_class = _normalize(row)
            print(f"{rname},{us:.2f},{derived:.6g},{route},{shape_class}")
        if args.json is not None:
            write_json(name, rows, args.json)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
