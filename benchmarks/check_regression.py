"""Perf-trajectory gate: compare BENCH_<section>.json runs against a baseline.

Usage (from the repo root, after ``python -m benchmarks.run --json``):

    python -m benchmarks.check_regression BENCH_*.json
    python -m benchmarks.check_regression --baseline benchmarks/baseline.json \
        --threshold 2.0 BENCH_dispatch.json
    python -m benchmarks.check_regression --write-baseline BENCH_*.json

The committed ``benchmarks/baseline.json`` is nested ``{section: {row: us}}``;
each ``BENCH_<section>.json`` (flat ``{row: us}``, section taken from the file
name) is compared row-by-row.  Rows slower than ``threshold``× baseline print a
``::warning::`` annotation (rendered inline by GitHub Actions) — **warn, never
fail**: shared-runner noise must not break the build, the trajectory is for
humans reading the annotations and the uploaded artifacts.  Exit status is 0
unless the inputs themselves are unusable (missing/corrupt files) or
``--strict`` is given, which turns regressions into a non-zero exit for local
use.

Rows or sections *absent from the baseline* (the expected skew whenever a new
benchmark section lands) print ``::notice::`` annotations — informational,
never a warning, never a crash.

``--write-baseline`` refreshes the baseline from the given runs instead of
comparing; it merges section-wise, so a partial ``--section`` run updates only
its own sections and keeps the rest of the committed baseline.

BENCH rows may be bare floats (legacy) or self-describing objects
(``{"us":…, "route":…, "shape_class":…}``, from telemetry-aware sections);
both are accepted, and ``--write-baseline`` normalises to plain floats so the
committed baseline format is unchanged.

``--telemetry report.json`` additionally audits a telemetry snapshot
(``repro.obs`` ``write_json`` output): any kind whose measured/TME-predicted
ratio exceeds ``REPRO_TME_NOTICE_RATIO`` (default 10) prints a ``::notice::``
annotation.  Notice, never warning: on the CPU CI runner the ratio is *always*
enormous (the chip model is a TPU spec and the pallas route runs the kernel
interpreter) — the annotation tracks the trajectory, it does not gate.

Deliberately dependency-free (no jax import): CI runs it in seconds.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys
from typing import Dict

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")
_BENCH_RE = re.compile(r"BENCH_(?P<section>[A-Za-z0-9_]+)\.json$")
NOTICE_RATIO_VAR = "REPRO_TME_NOTICE_RATIO"
DEFAULT_NOTICE_RATIO = 10.0


def _us(value) -> float:
    """Timing of a BENCH row: bare float or self-describing {"us": ...}."""
    if isinstance(value, dict):
        return float(value.get("us", 0.0))
    return float(value)


def section_of(path: str) -> str:
    m = _BENCH_RE.search(os.path.basename(path))
    if not m:
        raise ValueError(f"{path}: expected a BENCH_<section>.json file name")
    return m.group("section")


def load_json(path: str) -> Dict:
    with open(path) as fh:
        data = json.load(fh)
    if not isinstance(data, dict):
        raise ValueError(f"{path}: expected a JSON object")
    return data


def compare(section: str, current: Dict[str, float],
            baseline: Dict[str, Dict[str, float]], threshold: float):
    """Yield (kind, message) pairs; kind is 'warning' | 'notice'.

    Rows (or whole sections) absent from the baseline are *expected* skew —
    every new benchmark section hits this on its first CI run — so they are
    notices, never warnings, and never a crash.  Refresh the baseline with
    ``--write-baseline`` once the new rows are intentional.
    """
    base_rows = baseline.get(section)
    if base_rows is None:
        yield ("notice", f"{section}: no baseline section; "
                         f"{len(current)} row(s) recorded only — refresh with "
                         "--write-baseline")
        return
    for name, value in sorted(current.items()):
        us = _us(value)
        base_value = base_rows.get(name)
        if base_value is None:
            yield ("notice", f"{section}: new row {name} ({us:.2f} us) "
                             "not in baseline")
            continue
        base = _us(base_value)
        if base <= 0.0 or us <= 0.0:
            continue
        ratio = us / base
        if ratio > threshold:
            yield ("warning", f"perf regression {name}: {us:.2f} us vs "
                              f"baseline {base:.2f} us ({ratio:.2f}x > "
                              f"{threshold:g}x)")
    for name in sorted(set(base_rows) - set(current)):
        yield ("notice",
               f"{section}: baseline row {name} missing from this run")


def audit_telemetry(snapshot: Dict, notice_ratio: float):
    """Yield messages for kinds whose measured/TME ratio exceeds the notice
    threshold.  Aggregates counters per (kind, route) — same grouping as
    ``repro.obs.report`` — and skips entries with no TME prediction (event-only
    kinds like solver.* / serve.*)."""
    agg: Dict[tuple, Dict[str, float]] = {}
    for c in snapshot.get("counters", []):
        key = (c.get("kind", "?"), c.get("route", ""))
        slot = agg.setdefault(key, {"us": 0.0, "tme_us": 0.0})
        slot["us"] += float(c.get("us", 0.0))
        slot["tme_us"] += float(c.get("tme_us", 0.0))
    for (kind, route), slot in sorted(agg.items()):
        if slot["tme_us"] <= 0.0 or slot["us"] <= 0.0:
            continue
        ratio = slot["us"] / slot["tme_us"]
        if ratio > notice_ratio:
            yield (f"telemetry {kind}/{route or '-'}: measured/TME ratio "
                   f"{ratio:.1f}x > {notice_ratio:g}x "
                   f"(chip model: {snapshot.get('chip', '?')})")


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("files", nargs="+", metavar="BENCH_section.json")
    parser.add_argument("--baseline", default=DEFAULT_BASELINE)
    parser.add_argument("--threshold", type=float, default=2.0,
                        help="warn when current > threshold * baseline "
                             "(default 2.0)")
    parser.add_argument("--strict", action="store_true",
                        help="exit 1 on regressions (local use; CI warns only)")
    parser.add_argument("--write-baseline", action="store_true",
                        help="(re)write the baseline from these runs instead "
                             "of comparing")
    parser.add_argument("--telemetry", default=None, metavar="SNAPSHOT.json",
                        help="also audit a repro.obs telemetry snapshot: "
                             "::notice:: any kind whose measured/TME ratio "
                             f"exceeds ${NOTICE_RATIO_VAR} "
                             f"(default {DEFAULT_NOTICE_RATIO:g})")
    args = parser.parse_args(argv)

    runs = {section_of(p): load_json(p) for p in args.files}

    if args.write_baseline:
        # Merge-aware: replace only the sections present in this run, keep
        # the rest of the committed baseline (a partial --section run must
        # not silently drop the other sections' history).  Self-describing
        # rows normalise to plain floats — baseline format is unchanged.
        merged: Dict[str, Dict[str, float]] = {}
        if os.path.exists(args.baseline):
            merged.update(load_json(args.baseline))
        merged.update({sec: {name: _us(v) for name, v in rows.items()}
                       for sec, rows in runs.items()})
        with open(args.baseline, "w") as fh:
            json.dump(dict(sorted(merged.items())), fh, indent=2,
                      sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.baseline}: {len(runs)} section(s) refreshed, "
              f"{len(merged)} total")
        return 0

    baseline = load_json(args.baseline)
    regressions = 0
    for section, current in sorted(runs.items()):
        for kind, msg in compare(section, current, baseline, args.threshold):
            if kind == "warning":
                regressions += 1
                # GitHub Actions annotation; plain prefix everywhere else.
                print(f"::warning title=benchmark regression::{msg}")
            else:
                print(f"::notice title=benchmark skew::{msg}")
    if args.telemetry:
        notice_ratio = float(os.environ.get(NOTICE_RATIO_VAR,
                                            DEFAULT_NOTICE_RATIO))
        for msg in audit_telemetry(load_json(args.telemetry), notice_ratio):
            print(f"::notice title=TME model error::{msg}")
    total = sum(len(v) for v in runs.values())
    print(f"checked {total} rows across {len(runs)} section(s): "
          f"{regressions} regression(s) > {args.threshold:g}x")
    return 1 if (args.strict and regressions) else 0


if __name__ == "__main__":
    sys.exit(main())
