"""Perf-trajectory gate: compare BENCH_<section>.json runs against a baseline.

Usage (from the repo root, after ``python -m benchmarks.run --json``):

    python -m benchmarks.check_regression BENCH_*.json
    python -m benchmarks.check_regression --baseline benchmarks/baseline.json \
        --threshold 2.0 BENCH_dispatch.json
    python -m benchmarks.check_regression --write-baseline BENCH_*.json

The committed ``benchmarks/baseline.json`` is nested ``{section: {row: us}}``;
each ``BENCH_<section>.json`` (flat ``{row: us}``, section taken from the file
name) is compared row-by-row.  Rows slower than ``threshold``× baseline print a
``::warning::`` annotation (rendered inline by GitHub Actions) — **warn, never
fail**: shared-runner noise must not break the build, the trajectory is for
humans reading the annotations and the uploaded artifacts.  Exit status is 0
unless the inputs themselves are unusable (missing/corrupt files) or
``--strict`` is given, which turns regressions into a non-zero exit for local
use.

Rows or sections *absent from the baseline* (the expected skew whenever a new
benchmark section lands) print ``::notice::`` annotations — informational,
never a warning, never a crash.

``--write-baseline`` refreshes the baseline from the given runs instead of
comparing; it merges section-wise, so a partial ``--section`` run updates only
its own sections and keeps the rest of the committed baseline.

Deliberately dependency-free (no jax import): CI runs it in seconds.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys
from typing import Dict

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")
_BENCH_RE = re.compile(r"BENCH_(?P<section>[A-Za-z0-9_]+)\.json$")


def section_of(path: str) -> str:
    m = _BENCH_RE.search(os.path.basename(path))
    if not m:
        raise ValueError(f"{path}: expected a BENCH_<section>.json file name")
    return m.group("section")


def load_json(path: str) -> Dict:
    with open(path) as fh:
        data = json.load(fh)
    if not isinstance(data, dict):
        raise ValueError(f"{path}: expected a JSON object")
    return data


def compare(section: str, current: Dict[str, float],
            baseline: Dict[str, Dict[str, float]], threshold: float):
    """Yield (kind, message) pairs; kind is 'warning' | 'notice'.

    Rows (or whole sections) absent from the baseline are *expected* skew —
    every new benchmark section hits this on its first CI run — so they are
    notices, never warnings, and never a crash.  Refresh the baseline with
    ``--write-baseline`` once the new rows are intentional.
    """
    base_rows = baseline.get(section)
    if base_rows is None:
        yield ("notice", f"{section}: no baseline section; "
                         f"{len(current)} row(s) recorded only — refresh with "
                         "--write-baseline")
        return
    for name, us in sorted(current.items()):
        base = base_rows.get(name)
        if base is None:
            yield ("notice", f"{section}: new row {name} ({us:.2f} us) "
                             "not in baseline")
            continue
        if base <= 0.0 or us <= 0.0:
            continue
        ratio = us / base
        if ratio > threshold:
            yield ("warning", f"perf regression {name}: {us:.2f} us vs "
                              f"baseline {base:.2f} us ({ratio:.2f}x > "
                              f"{threshold:g}x)")
    for name in sorted(set(base_rows) - set(current)):
        yield ("notice",
               f"{section}: baseline row {name} missing from this run")


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("files", nargs="+", metavar="BENCH_section.json")
    parser.add_argument("--baseline", default=DEFAULT_BASELINE)
    parser.add_argument("--threshold", type=float, default=2.0,
                        help="warn when current > threshold * baseline "
                             "(default 2.0)")
    parser.add_argument("--strict", action="store_true",
                        help="exit 1 on regressions (local use; CI warns only)")
    parser.add_argument("--write-baseline", action="store_true",
                        help="(re)write the baseline from these runs instead "
                             "of comparing")
    args = parser.parse_args(argv)

    runs = {section_of(p): load_json(p) for p in args.files}

    if args.write_baseline:
        # Merge-aware: replace only the sections present in this run, keep
        # the rest of the committed baseline (a partial --section run must
        # not silently drop the other sections' history).
        merged: Dict[str, Dict[str, float]] = {}
        if os.path.exists(args.baseline):
            merged.update(load_json(args.baseline))
        merged.update(runs)
        with open(args.baseline, "w") as fh:
            json.dump(dict(sorted(merged.items())), fh, indent=2,
                      sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.baseline}: {len(runs)} section(s) refreshed, "
              f"{len(merged)} total")
        return 0

    baseline = load_json(args.baseline)
    regressions = 0
    for section, current in sorted(runs.items()):
        for kind, msg in compare(section, current, baseline, args.threshold):
            if kind == "warning":
                regressions += 1
                # GitHub Actions annotation; plain prefix everywhere else.
                print(f"::warning title=benchmark regression::{msg}")
            else:
                print(f"::notice title=benchmark skew::{msg}")
    total = sum(len(v) for v in runs.values())
    print(f"checked {total} rows across {len(runs)} section(s): "
          f"{regressions} regression(s) > {args.threshold:g}x")
    return 1 if (args.strict and regressions) else 0


if __name__ == "__main__":
    sys.exit(main())
