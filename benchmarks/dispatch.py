"""Dispatch-layer benchmarks: plan-cache amortisation and XLA vs Pallas routing.

The route rows carry telemetry provenance (route + shape_class columns via
``repro.obs.probe``) so the BENCH artifact is self-describing; the probe runs
one extra untimed call after the timing loop, telemetry stays off while timing.

CSV rows (name,us_per_call,derived):
  dispatch/plan_cold/us        — first-touch make_plan + Garner setup
                                 (derived = r of the resolved plan);
  dispatch/plan_cached/us      — same key through dispatch.get_plan
                                 (derived = cold/warm speedup);
  dispatch/route_xla/us        — emulated GEMM via the XLA reference path
                                 (derived = GFLOP/s of the equivalent FP64 GEMM);
  dispatch/route_pallas/us     — same GEMM via the fused Pallas kernel
                                 (interpret on CPU, Mosaic on TPU; same derived);
  dispatch/policy_dot_warm/us  — Policy.dot hot path with a warm plan cache
                                 (derived = us spent per call resolving the plan,
                                 measured by timing get_plan alone).

On this CPU container the pallas row runs the kernel interpreter, so its
wall-clock is a machinery check, not a perf claim — the TPU roofline story
lives in the launch tooling.  The cache rows are backend-independent.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch, ozaki2
from repro.core.policy import Policy
from repro.obs import telemetry as obs

Row = Tuple[str, float, float]


def _provenance(fn) -> Tuple[str, str]:
    """(route, shape_class) of fn's dispatch call, via a telemetry probe."""
    _, ev = obs.probe(fn)
    return (ev.route, ev.shape_class) if ev is not None else ("", "")

_K = 256
_SHAPE = (128, _K, 128)


def _timed(fn, reps: int = 3) -> float:
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _timed_host(fn, reps: int = 200) -> float:
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def dispatch_paths() -> List[Row]:
    rows: List[Row] = []
    m, k, n = _SHAPE
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)))
    b = jnp.asarray(rng.standard_normal((k, n)))

    # --- plan cache: cold make_plan+Garner vs cached lookup -------------------
    dispatch.clear_plan_cache()

    def cold():
        from repro.core import moduli as moduli_lib
        moduli_lib.garner_constants.cache_clear()
        plan = ozaki2.make_plan(k)
        plan.garner
        return plan

    us_cold = _timed_host(cold, reps=50)
    plan = dispatch.get_plan(k)
    us_warm = _timed_host(lambda: dispatch.get_plan(k))
    rows.append(("dispatch/plan_cold/us", us_cold, float(plan.r)))
    rows.append(("dispatch/plan_cached/us", us_warm,
                 us_cold / max(us_warm, 1e-9)))

    # --- routing: XLA reference vs fused Pallas kernel ------------------------
    flops = 2.0 * m * k * n
    us_xla = _timed(lambda: dispatch.matmul(a, b, plan=plan, mode="xla"))
    rows.append(("dispatch/route_xla/us", us_xla, flops / us_xla * 1e-3,
                 *_provenance(lambda: dispatch.matmul(a, b, plan=plan,
                                                      mode="xla"))))
    us_pal = _timed(lambda: dispatch.matmul(a, b, plan=plan, mode="pallas"),
                    reps=1)
    rows.append(("dispatch/route_pallas/us", us_pal, flops / us_pal * 1e-3,
                 *_provenance(lambda: dispatch.matmul(a, b, plan=plan,
                                                      mode="pallas"))))

    # --- Policy.dot hot path with a warm cache --------------------------------
    # Pinned to the xla route so the row times the same code path in both legs
    # of the CI REPRO_DISPATCH matrix (one committed baseline value).
    pol = Policy("ozaki2_int8")
    with dispatch.mode_scope("xla"):
        us_dot = _timed(lambda: pol.dot(a, b))
    us_lookup = _timed_host(lambda: dispatch.get_plan(k, pol.payload_bits,
                                                      "int8"))
    rows.append(("dispatch/policy_dot_warm/us", us_dot, us_lookup))
    return rows
