"""Reproduction of the paper's analytic tables (1–5) from our TME implementation.

Each function returns a list of CSV rows ``(name, us_per_call, derived)`` where
``derived`` carries the table value.  The tables are *analytic* in the paper (it has
no implementation); here they are regenerated from ``repro.core.tme`` so that any
drift between our model and the paper's published numbers is visible.  Known paper
-internal inconsistencies are flagged in EXPERIMENTS.md (e.g. Table 3's H100 dense-
GEMM "~1.0x" contradicts Table 4's 198 vs 67 TFLOPS = 2.95x; our model agrees with
Table 4).
"""

from __future__ import annotations

from typing import List, Tuple

from repro.core import ozaki1, tme
from repro.core import moduli as moduli_lib

Row = Tuple[str, float, float]


def table1_slice_counts() -> List[Row]:
    """Paper Table 1: Ozaki-I slice counts from the accumulator bound (eq. 3)."""
    rows: List[Row] = []
    cfgs = [
        ("fp16_fp32acc", 24, 11),
        ("int8_int32acc", 31, 7),
        ("fp8_fp32acc", 24, 4),
    ]
    for name, w_acc, input_bits in cfgs:
        for k in (256, 1024, 4096, 16384):
            b = ozaki1.slice_width(k, w_acc=w_acc, input_bits=input_bits)
            s = ozaki1.slice_count(53, b)
            rows.append((f"table1/{name}/k{k}", 0.0, float(s)))
    return rows


def table2_architectures() -> List[Row]:
    rows: List[Row] = []
    for chip in tme.CHIPS.values():
        rows.append((f"table2/{chip.name}/fp64_vector_tflops", 0.0, chip.fp64_vector))
        rows.append((f"table2/{chip.name}/fp8_tflops", 0.0, chip.fp8))
        rows.append((f"table2/{chip.name}/int8_tops", 0.0, chip.int8))
        rows.append((f"table2/{chip.name}/hbm_tbps", 0.0, chip.hbm_tbps))
        rows.append((f"table2/{chip.name}/native_ridge_flops_per_byte", 0.0,
                     chip.fp64_vector / chip.hbm_tbps))
    return rows


def table3_speedups() -> List[Row]:
    rows: List[Row] = []
    for rec in tme.table3_speedups(r=10):
        for chip in ("H100", "B200", "B300", "R200"):
            rows.append((f"table3/{rec['workload']}/{chip}", 0.0, rec[chip]))
    return rows


def table4_h100_baseline() -> List[Row]:
    rows: List[Row] = []
    for rec in tme.table4_h100_baseline(r=10):
        for chip in ("H100", "B200", "B300", "R200"):
            rows.append(
                (f"table4/{rec['workload']}/{rec['path']}/{chip}_tflops", 0.0,
                 rec[chip]))
            rows.append(
                (f"table4/{rec['workload']}/{rec['path']}/{chip}_vs_h100", 0.0,
                 rec[f"{chip}_vs_h100"]))
    return rows


def table5_substrates() -> List[Row]:
    rows: List[Row] = []
    for rec in tme.table5_substrates(r=10):
        rows.append((f"table5/{rec['chip']}/ozaki_int8_ceiling", 0.0,
                     rec["ozaki_int8_ceiling"]))
        rows.append((f"table5/{rec['chip']}/ozaki_fp8_ceiling", 0.0,
                     rec["ozaki_fp8_ceiling"]))
        rows.append((f"table5/{rec['chip']}/fp8_advantage", 0.0,
                     rec["fp8_advantage"]))
    return rows


def moduli_requirements() -> List[Row]:
    """§2.3: r ∈ [13,16] published for INT8 FP64-grade emulation — our derivation."""
    rows: List[Row] = []
    for k in (256, 1024, 4096, 16384, 131072):
        rows.append((f"moduli/required_r/k{k}", 0.0,
                     float(moduli_lib.required_r(k, 53))))
    return rows
