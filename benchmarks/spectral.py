"""Spectral-subsystem benchmarks: emulated FFT accuracy/latency + TME model.

CSV rows (name,us_per_call,derived):
  spectral/fft_n{64,256,384,1024}/us — emulated FFT through the XLA dispatch
                                       route (derived = relative l2 error vs
                                       the jnp.fft.fft FP64 oracle);
  spectral/fft_pallas_n256/us        — same transform on the fused-kernel route
                                       (derived = max |pallas - xla|, expected
                                       exactly 0: the routes are bit-identical);
  spectral/rfft_n384/us              — real-input transform (derived = rel err
                                       vs jnp.fft.rfft);
  spectral/poisson2d_32x32/us        — spectral Poisson direct solve (derived =
                                       true relative residual);
  spectral/compensated_dot_n4096/us  — Dot2 in f32 (derived = plain-f32 error /
                                       compensated-f32 error vs the f64 oracle);
  spectral/tme_fft_b300_speedup      — TME-projected emulated-over-native FFT
                                       speedup on B300 (model row, us = 0).

On this CPU container the pallas row runs the kernel interpreter — a
machinery/parity check, not a perf claim (same caveat as the dispatch section).
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import spectral
from repro.core import compensated, tme
from repro.hpc import poisson

Row = Tuple[str, float, float]


def _timed(fn, reps: int = 3) -> Tuple[float, object]:
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def _rel(got, want) -> float:
    got, want = np.asarray(got), np.asarray(want)
    return float(np.linalg.norm(got - want) / np.linalg.norm(want))


def spectral_section() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)

    for n in (64, 256, 384, 1024):
        x = jnp.asarray(rng.standard_normal(n) + 1j * rng.standard_normal(n))
        us, got = _timed(lambda x=x: spectral.fft(x, mode="xla"))
        rows.append((f"spectral/fft_n{n}/us", us, _rel(got, jnp.fft.fft(x))))

    x = jnp.asarray(rng.standard_normal(256) + 1j * rng.standard_normal(256))
    us, got_p = _timed(lambda: spectral.fft(x, mode="pallas"), reps=1)
    got_x = spectral.fft(x, mode="xla")
    rows.append(("spectral/fft_pallas_n256/us", us,
                 float(jnp.max(jnp.abs(got_p - got_x)))))

    xr = jnp.asarray(rng.standard_normal(384))
    us, got = _timed(lambda: spectral.rfft(xr, mode="xla"))
    rows.append(("spectral/rfft_n384/us", us, _rel(got, jnp.fft.rfft(xr))))

    f, _ = poisson.manufactured_rhs((32, 32), seed=1)
    us, _ = _timed(lambda: poisson.poisson_solve_periodic(f, mode="xla"))
    rows.append(("spectral/poisson2d_32x32/us", us,
                 poisson.poisson_solve_checked(f, mode="xla").residual))

    a32 = jnp.asarray(rng.standard_normal(4096), jnp.float32)
    b32 = jnp.asarray(rng.standard_normal(4096), jnp.float32)
    exact = float(np.dot(np.asarray(a32, np.float64), np.asarray(b32, np.float64)))
    us, comp = _timed(lambda: compensated.compensated_dot(a32, b32))
    plain_err = abs(float(jnp.dot(a32, b32)) - exact)
    comp_err = abs(float(comp) - exact)
    rows.append(("spectral/compensated_dot_n4096/us", us,
                 plain_err / max(comp_err, 1e-30)))

    import dataclasses
    params = dataclasses.replace(tme.EmulationParams.ozaki2(r=10, substrate="fp8"),
                                 gamma=tme.garner_gamma(tme.B300, 10))
    n_model = 1 << 18
    native = tme.fft_native_time(n_model, tme.B300, batch=4096)
    emu = tme.fft_emulated_time(n_model, tme.B300, params, batch=4096)
    gamma_s = sum(params.gamma * s.n_out
                  for s in tme.bailey_fft_stages(n_model, 4096))
    rows.append(("spectral/tme_fft_b300_speedup", 0.0, native / emu))
    rows.append(("spectral/tme_fft_b300_gamma_fraction", 0.0, gamma_s / emu))
    return rows
