"""Blocked-EFT reduction benchmarks: the §7.1(a) BLAS-1 fast path.

CSV rows (name,us_per_call,derived):
  reductions/dot_blocked_n{4096,65536}/us — jitted blocked Dot2 in f32
                                            (derived = plain-f32 error /
                                            compensated-f32 error vs the f64
                                            oracle — the accuracy win);
  reductions/dot_scan_n4096/us            — the retained element-wise scan
                                            reference (derived = scan_us /
                                            blocked_us, the blocking speedup;
                                            the acceptance floor is 10x);
  reductions/dot_plain_n4096/us           — un-compensated jnp.dot (derived =
                                            |blocked - scan| result delta,
                                            expected 0: same math);
  reductions/sum_blocked_n4096/us         — blocked Neumaier sum (derived =
                                            plain/compensated error ratio vs
                                            math.fsum);
  reductions/norm_n4096/us                — FTZ-safe compensated 2-norm
                                            (derived = rel err vs the f64
                                            numpy oracle);
  reductions/cg48_xla/us                  — dense emulated CG, XLA route:
  reductions/cg48_pallas/us                 reductions composed with the
                                            dispatch seam (derived = iteration
                                            count; the routes must agree).

On this CPU container the pallas CG row runs the kernel interpreter — a
machinery/parity check, not a perf claim (same caveat as the kernels section).

The blocked-dot and CG rows carry telemetry provenance (route + shape_class
CSV columns via ``repro.obs.probe``, one extra untimed call after timing).
"""

from __future__ import annotations

import math
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compensated, dispatch
from repro.hpc import cg
from repro.obs import telemetry as obs

Row = Tuple[str, float, float]


def _timed(fn, reps: int = 5) -> Tuple[float, object]:
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def _dot_rows(rng) -> List[Row]:
    rows: List[Row] = []
    for n in (4096, 65536):
        a = jnp.asarray(rng.standard_normal(n), jnp.float32)
        b = jnp.asarray(rng.standard_normal(n), jnp.float32)
        exact = float(np.dot(np.asarray(a, np.float64),
                             np.asarray(b, np.float64)))
        us_blk, blk = _timed(lambda a=a, b=b: compensated.compensated_dot(a, b))
        plain_err = abs(float(jnp.dot(a, b)) - exact)
        comp_err = abs(float(blk) - exact)
        _, ev = obs.probe(lambda a=a, b=b: compensated.compensated_dot(a, b))
        route, cls = (ev.route, ev.shape_class) if ev is not None else ("", "")
        rows.append((f"reductions/dot_blocked_n{n}/us", us_blk,
                     plain_err / max(comp_err, 1e-30), route, cls))
        if n == 4096:
            us_scan, scan = _timed(
                lambda a=a, b=b: compensated.compensated_dot_scan(a, b), reps=1)
            rows.append(("reductions/dot_scan_n4096/us", us_scan,
                         us_scan / max(us_blk, 1e-9)))
            us_plain, _ = _timed(lambda a=a, b=b: jnp.dot(a, b))
            rows.append(("reductions/dot_plain_n4096/us", us_plain,
                         abs(float(blk) - float(scan))))
    return rows


def _sum_norm_rows(rng) -> List[Row]:
    # Ill-conditioned summands so the compensation is load-bearing.
    x = np.asarray(rng.standard_normal(4096) * 10.0 ** rng.integers(
        0, 8, 4096), np.float32)
    xj = jnp.asarray(x)
    exact = math.fsum(np.asarray(x, np.float64).tolist())
    us, comp = _timed(lambda: compensated.neumaier_sum(xj))
    plain_err = abs(float(jnp.sum(xj)) - exact)
    comp_err = abs(float(comp) - exact)
    rows = [("reductions/sum_blocked_n4096/us", us,
             plain_err / max(comp_err, 1e-30))]

    v = jnp.asarray(rng.standard_normal(4096), jnp.float32)
    ref = np.linalg.norm(np.asarray(v, np.float64))
    us, nrm = _timed(lambda: compensated.compensated_norm(v))
    rows.append(("reductions/norm_n4096/us", us,
                 abs(float(nrm) - ref) / ref))
    return rows


def _cg_rows(rng) -> List[Row]:
    n = 48
    m = rng.standard_normal((n, n))
    a = jnp.asarray(m @ m.T + n * np.eye(n))
    b = jnp.asarray(rng.standard_normal(n))
    rows: List[Row] = []
    results = {}
    for mode in ("xla", "pallas"):
        us, _ = _timed(lambda mode=mode: cg.cg_solve_dense(
            a, b, mode=mode, tol=1e-10, maxiter=2 * n,
            record_plain=False).x, reps=1)
        res = cg.cg_solve_dense(a, b, mode=mode, tol=1e-10, maxiter=2 * n,
                                record_plain=False)
        results[mode] = res
        # Provenance from the solve's representative matvec (a probe of the
        # whole solve would report its *last* routed event — a reduce, always
        # xla — not the route under test).
        _, ev = obs.probe(lambda mode=mode: dispatch.matmul(
            a, b[:, None], mode=mode))
        route, cls = (ev.route, ev.shape_class) if ev is not None else ("", "")
        rows.append((f"reductions/cg{n}_{mode}/us", us, float(res.iters),
                     route, cls))
    # Route parity: the dispatch routes are bit-identical, so the composed
    # solves must agree exactly — surfaced in CSV output, asserted in tests.
    delta = float(jnp.max(jnp.abs(results["xla"].x - results["pallas"].x)))
    rows.append((f"reductions/cg{n}_route_delta", 0.0, delta))
    return rows


def reductions_section() -> List[Row]:
    rng = np.random.default_rng(0)
    return _dot_rows(rng) + _sum_norm_rows(rng) + _cg_rows(rng)
