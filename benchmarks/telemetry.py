"""Telemetry benchmark section — measured-vs-TME through the seam's own
instrument.

Unlike the other sections, nothing here is timed by hand: every op runs under
``REPRO_TELEMETRY`` trace scope and the wall-clock comes from the telemetry
counters themselves (``block_until_ready``-fenced inside ``obs.op_end``), so
this section exercises the recording path end to end while producing the
measured-vs-TME table for all five fused kinds + reduce, on *both* routes.

CSV rows (name,us_per_call,derived,route,shape_class):
  telemetry/<kind>_<route>/us — mean measured μs per call from the counters;
                                derived = measured/TME-predicted ratio (the
                                model-error ratio; large on CPU — the chip
                                model is the TPU v5e spec and the pallas
                                route runs the interpreter — recorded for the
                                trajectory, gated as ::notice:: by
                                ``check_regression --telemetry``).

The SpMV rows use a 24-bit-payload plan (r = 7): the interpreted gather graph
at the default r = 15 plan costs 10+ minutes of XLA-CPU compile (ROADMAP).

Side artifact: when ``REPRO_TELEMETRY_JSON`` names a path, the full telemetry
snapshot (counters + caches + trace ring) is written there — the per-leg
``telemetry-<mode>`` CI artifact.
"""

from __future__ import annotations

import os
import sys
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import compensated, dispatch, ozaki2
from repro.obs import report, telemetry as obs

Row = Tuple[str, float, float, str, str]

JSON_VAR = "REPRO_TELEMETRY_JSON"
_REPS = 3


def _workloads():
    """(callable, reps) covering every fused kind + reduce, per route."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((128, 256)))
    b = jnp.asarray(rng.standard_normal((256, 128)))
    v = jnp.asarray(rng.standard_normal((256, 4)))
    u = jnp.asarray(rng.standard_normal((32, 32, 32)))
    c = jnp.asarray(np.array([6.0, -1, -1, -1, -1, -1, -1]))
    plan_r7 = ozaki2.make_plan(8, payload_bits=24, margin_bits=4)
    val = jnp.asarray(rng.standard_normal((256, 8)))
    col = jnp.asarray(rng.integers(0, 256, (256, 8)).astype(np.int32))
    x = jnp.asarray(rng.standard_normal(256))
    d1 = jnp.asarray(rng.standard_normal(4096))
    d2 = jnp.asarray(rng.standard_normal(4096))
    q = jnp.asarray(rng.standard_normal((32, 16)))
    kk = jnp.asarray(rng.standard_normal((32, 16)))
    vv = jnp.asarray(rng.standard_normal((32, 16)))
    causal = jnp.tril(jnp.ones((32, 32), jnp.int8))

    work = []
    for mode in ("xla", "pallas"):
        # The pallas leg runs the kernel interpreter on CPU: one rep each.
        reps = _REPS if mode == "xla" else 1
        work.append((lambda mode=mode: dispatch.matmul(a, b, mode=mode), reps))
        work.append((lambda mode=mode: dispatch.matmul(a, v, mode=mode), reps))
        work.append((lambda mode=mode: dispatch.stencil7(u, c, mode=mode),
                     reps))
        work.append((lambda mode=mode: dispatch.spmv(
            val, col, x, plan=plan_r7, br=128, mode=mode), reps))
        work.append((lambda mode=mode: dispatch.attention(
            q, kk, vv, mask=causal, mode=mode), reps))
    work.append((lambda: compensated.compensated_dot(d1, d2), _REPS))
    return work


def telemetry_section() -> List[Row]:
    obs.reset()
    with obs.telemetry_scope("trace"):
        for fn, reps in _workloads():
            fn()                      # warm-up (compile) outside the counters
        obs.reset()
        for fn, reps in _workloads():
            for _ in range(reps):
                fn()
        snap = obs.snapshot()
        json_path = os.environ.get(JSON_VAR)
        if json_path:
            obs.write_json(json_path)

    # The human-readable measured-vs-TME table rides stderr so the CSV on
    # stdout stays machine-parseable.
    print(report.render(report.table_rows(snap), chip=snap["chip"]),
          file=sys.stderr)

    rows: List[Row] = []
    for c in snap["counters"]:
        calls = max(int(c["calls"]), 1)
        ratio = c["us"] / c["tme_us"] if c["tme_us"] > 0 else 0.0
        rows.append((f"telemetry/{c['kind']}_{c['route']}/us",
                     c["us"] / calls, ratio, c["route"], c["shape_class"]))
    return rows
