"""Kernel benchmarks: wall-clock on CPU-interpret (machinery check) plus the
*structural* β accounting that the paper's §5/Appendix D analysis is about.

derived column:
  wallclock rows — CPU interpret μs (not TPU perf; the roofline story for TPU lives
                   in EXPERIMENTS.md §Roofline from the compiled dry-run);
  beta rows      — HBM bytes of the emulated kernel / bytes of the native-FP64
                   kernel, computed from the actual operand/result shapes.  The
                   paper's claim is β = 1 for f64/ds output and (8+r)/16-ish for
                   digits mode; this prints the exact numbers.
  route rows     — xla vs pallas through the dispatch entry points
                   (``ops.ozaki_spmv_bell`` / ``ops.ozaki_stencil7`` with
                   ``mode=``); derived = max |pallas - xla|, expected exactly 0
                   (the routes are bit-identical).

Every row pins its dispatch mode so the perf trajectory measures the same code
path in both legs of the CI ``REPRO_DISPATCH`` matrix.  The SpMV pallas-route
row uses a 24-bit-payload plan (r = 7): the interpreted gather graph with the
default r = 15 plan costs *minutes* of XLA-CPU compile (ROADMAP), which is a
parity-oracle price the benchmark lane must not pay.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ozaki2
from repro.kernels import ops
from repro.obs import telemetry as obs

Row = Tuple[str, float, float]


def _provenance(fn) -> Tuple[str, str]:
    """(route, shape_class) of fn's dispatch call, via a telemetry probe —
    one extra untimed call so the BENCH route rows are self-describing."""
    _, ev = obs.probe(fn)
    return (ev.route, ev.shape_class) if ev is not None else ("", "")


def _timed(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _beta(in_native: int, out_native: int, in_emu: int, out_emu: int) -> float:
    return (in_emu + out_emu) / (in_native + out_native)


def all_kernels() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)

    # --- GEMM ---------------------------------------------------------------
    m = k = n = 128
    a = jnp.asarray(rng.standard_normal((m, k)))
    b = jnp.asarray(rng.standard_normal((k, n)))
    plan = ozaki2.make_plan(k)
    for rep in ("f64", "digits", "ds"):
        us = _timed(lambda rep=rep: ops.ozaki_gemm(a, b, plan=plan, out_rep=rep,
                                                   bm=64, bn=64, bk=64))
        out_bytes = {"f64": 8, "ds": 8, "digits": plan.r}[rep] * m * n
        beta = _beta((m * k + k * n) * 8, m * n * 8,
                     (m * k + k * n) * 8, out_bytes)
        rows.append((f"kernel_gemm/{rep}/beta", us, beta))

    # --- batched GEMV (B = 8 and 2: the Table 3/4 rows) ----------------------
    M, N = 512, 256
    A = jnp.asarray(rng.standard_normal((M, N)))
    for B in (8, 2):
        X = jnp.asarray(rng.standard_normal((N, B)))
        planv = ozaki2.make_plan(N)
        for rep in ("f64", "digits"):
            us = _timed(lambda rep=rep, X=X: ops.ozaki_gemv(
                A, X, plan=planv, out_rep=rep, bm=128, bk=128))
            out_bytes = {"f64": 8, "digits": planv.r}[rep] * M * B
            beta = _beta((M * N + N * B) * 8, M * B * 8,
                         (M * N + N * B) * 8, out_bytes)
            rows.append((f"kernel_gemv_b{B}/{rep}/beta", us, beta))

    # --- 7-point stencil ------------------------------------------------------
    # mode="pallas" pins the wallclock rows to the fused kernel (the CPU auto
    # route is now the jnp reference via the dispatch seam).
    u = jnp.asarray(rng.standard_normal((32, 32, 32)))
    c = jnp.asarray(np.array([6.0, -1, -1, -1, -1, -1, -1]))
    for rep in ("f64", "digits", "ds"):
        usx = _timed(lambda rep=rep: ops.ozaki_stencil7(u, c, out_rep=rep,
                                                        bz=8, mode="pallas"))
        plan_s = ozaki2.make_plan(8, margin_bits=4)
        npts = 32 ** 3
        out_bytes = {"f64": 8, "ds": 8, "digits": plan_s.r}[rep] * npts
        beta = _beta(npts * 8, npts * 8, npts * 8, out_bytes)
        rows.append((f"kernel_stencil/{rep}/beta", usx, beta))

    # --- Blocked-ELL SpMV ------------------------------------------------------
    Ms, Ns, bw = 1024, 1024, 16
    col = jnp.asarray(rng.integers(0, Ns, (Ms, bw)).astype(np.int32))
    val_np = rng.standard_normal((Ms, bw))
    val_np[rng.random((Ms, bw)) < 0.3] = 0.0
    val = jnp.asarray(val_np)
    x = jnp.asarray(rng.standard_normal(Ns))
    for rep in ("f64", "digits"):
        # mode="xla" pins these rows to the bit-identical jnp reference: the
        # interpreted Pallas SpMV pays a multi-minute XLA-CPU compile at the
        # default plan, which would hang the smoke lane.  The fused-kernel
        # machinery is covered by the bounded-plan route rows below (and on
        # TPU these same entry points measure the Mosaic kernel via auto).
        us = _timed(lambda rep=rep: ops.ozaki_spmv_bell(val, col, x, out_rep=rep,
                                                        br=256, mode="xla"))
        plan_v = ozaki2.make_plan(bw, margin_bits=4)
        out_bytes = {"f64": 8, "digits": plan_v.r}[rep] * Ms
        # native bytes: values + colidx + x-gather (cached ~1x) + y
        native = Ms * bw * 8 + Ms * bw * 4 + Ns * 8 + Ms * 8
        emu = Ms * bw * 8 + Ms * bw * 4 + Ns * 8 + out_bytes
        rows.append((f"kernel_spmv/{rep}/beta", us, emu / native))

    # --- dispatch-route comparison (the seam, both sides) ---------------------
    # derived on both rows of a pair = max |pallas - xla| (expected exactly 0:
    # the routes are bit-identical); outputs are computed once per route.
    # stencil: default plan, both routes are cheap on CPU.
    stencil_out = {}
    for mode in ("xla", "pallas"):
        us = _timed(lambda mode=mode: ops.ozaki_stencil7(u, c, bz=8, mode=mode))
        route, cls = _provenance(
            lambda mode=mode: ops.ozaki_stencil7(u, c, bz=8, mode=mode))
        stencil_out[mode] = (f"kernel_stencil/route_{mode}/us", us,
                             ops.ozaki_stencil7(u, c, bz=8, mode=mode),
                             route, cls)
    diff = float(jnp.max(jnp.abs(stencil_out["pallas"][2]
                                 - stencil_out["xla"][2])))
    rows.extend((name, us, diff, route, cls)
                for name, us, _, route, cls in stencil_out.values())

    # spmv: 24-bit payload (r = 7) bounds the interpreter compile to seconds.
    plan_r7 = ozaki2.make_plan(8, payload_bits=24, margin_bits=4)
    Mr, Nr, bwr = 256, 256, 8
    col_r = jnp.asarray(rng.integers(0, Nr, (Mr, bwr)).astype(np.int32))
    val_r = jnp.asarray(rng.standard_normal((Mr, bwr)))
    x_r = jnp.asarray(rng.standard_normal(Nr))
    spmv_out = {}
    for mode in ("xla", "pallas"):
        us = _timed(lambda mode=mode: ops.ozaki_spmv_bell(
            val_r, col_r, x_r, plan=plan_r7, br=128, mode=mode))
        route, cls = _provenance(lambda mode=mode: ops.ozaki_spmv_bell(
            val_r, col_r, x_r, plan=plan_r7, br=128, mode=mode))
        spmv_out[mode] = (f"kernel_spmv/route_{mode}/us", us,
                          ops.ozaki_spmv_bell(val_r, col_r, x_r, plan=plan_r7,
                                              br=128, mode=mode),
                          route, cls)
    diff = float(jnp.max(jnp.abs(spmv_out["pallas"][2] - spmv_out["xla"][2])))
    rows.extend((name, us, diff, route, cls)
                for name, us, _, route, cls in spmv_out.values())

    # --- padding-ratio -> beta (Appendix D) -----------------------------------
    for rho in (1.0, 2.0, 4.0):
        rows.append((f"kernel_spmv/padding_rho{rho}/beta_bound", 0.0, rho))
    return rows
