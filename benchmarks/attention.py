"""Fused emulated attention benchmarks — the seam's fifth kind, both routes.

Rows (name,us_per_call,derived,route,shape_class):
  kernel_attention/route_<mode>/us        — prefill (S = T) wall-clock per
                                            route; derived on both rows of the
                                            pair = max |pallas - xla| over the
                                            outputs, expected exactly 0 (the
                                            FlashAttention-style fused kernel
                                            and the seam-GEMM reference are
                                            bit-identical by construction).
  kernel_attention/decode_route_<mode>/us — same contract at the serving
                                            decode shape (S = 1 against a T
                                            deep cache).

Wall-clock on CPU measures the interpreter for the pallas route (machinery
check, not TPU perf) — the point of this section is the route-parity derived
column and the provenance (route, shape_class) telemetry attaches, which the
perf-trajectory CI records in both legs of the ``REPRO_DISPATCH`` matrix.
"""

from __future__ import annotations

from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from benchmarks.kernels import _provenance, _timed

Row = Tuple[str, float, float, str, str]


def attention_section() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)

    # --- prefill shape (S = T): causal mask, both routes -----------------------
    S, D = 64, 32
    q = jnp.asarray(rng.standard_normal((S, D)))
    k = jnp.asarray(rng.standard_normal((S, D)))
    v = jnp.asarray(rng.standard_normal((S, D)))
    causal = jnp.tril(jnp.ones((S, S), jnp.int8))
    pre = {}
    # reps=1 throughout: one emulated attention call costs seconds on CPU
    # (both routes run the full residue pipeline per kv block), and the smoke
    # lane runs this section in both REPRO_DISPATCH legs.
    for mode in ("xla", "pallas"):
        us = _timed(lambda mode=mode: ops.ozaki_attention(
            q, k, v, mask=causal, mode=mode), reps=1)
        route, cls = _provenance(lambda mode=mode: ops.ozaki_attention(
            q, k, v, mask=causal, mode=mode))
        pre[mode] = (f"kernel_attention/route_{mode}/us", us,
                     ops.ozaki_attention(q, k, v, mask=causal, mode=mode),
                     route, cls)
    diff = float(jnp.max(jnp.abs(pre["pallas"][2] - pre["xla"][2])))
    rows.extend((name, us, diff, route, cls)
                for name, us, _, route, cls in pre.values())

    # --- decode shape (S = 1, deep cache): padding mask, both routes -----------
    T = 96
    qd = jnp.asarray(rng.standard_normal((1, D)))
    kd = jnp.asarray(rng.standard_normal((T, D)))
    vd = jnp.asarray(rng.standard_normal((T, D)))
    valid = jnp.asarray((np.arange(T) < 80).astype(np.int8))[None, :]
    dec = {}
    for mode in ("xla", "pallas"):
        us = _timed(lambda mode=mode: ops.ozaki_attention(
            qd, kd, vd, mask=valid, mode=mode), reps=1)
        route, cls = _provenance(lambda mode=mode: ops.ozaki_attention(
            qd, kd, vd, mask=valid, mode=mode))
        dec[mode] = (f"kernel_attention/decode_route_{mode}/us", us,
                     ops.ozaki_attention(qd, kd, vd, mask=valid, mode=mode),
                     route, cls)
    diff = float(jnp.max(jnp.abs(dec["pallas"][2] - dec["xla"][2])))
    rows.extend((name, us, diff, route, cls)
                for name, us, _, route, cls in dec.values())
    return rows
