"""Empirical validation benchmarks the paper could not run (it is analytical).

error_vs_r     — §2.5/§2.4: observed componentwise error (in units of u64) versus the
                 moduli count r, for both substrates.  The paper reports 2–10 u for
                 bounded-condition inputs at full r; we measure the whole curve.
gemm_count     — Ozaki I Θ(S²) vs Ozaki II Θ(r) arithmetic-volume comparison.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ozaki1, ozaki2

Row = Tuple[str, float, float]
U64 = 2.0 ** -53


def _timed(fn, *args) -> Tuple[float, object]:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(3):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / 3 * 1e6, out


def error_vs_r() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    k = 512
    a = jnp.asarray(rng.standard_normal((64, k)))
    b = jnp.asarray(rng.standard_normal((k, 64)))
    ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    denom = np.abs(np.asarray(a)) @ np.abs(np.asarray(b))
    for substrate in ("int8", "fp8"):
        for r in (6, 8, 10, 12, 14, 16):
            plan = ozaki2.make_plan(k, r=r, substrate=substrate)
            us, c = _timed(ozaki2.emulated_matmul, a, b, plan)
            err = float(np.max(np.abs(np.asarray(c) - ref) / denom) / U64)
            rows.append((f"error_vs_r/{substrate}/r{r}", us, err))
    return rows


def ozaki1_vs_ozaki2_volume() -> List[Row]:
    rows: List[Row] = []
    for k in (1024, 4096, 16384):
        p1 = ozaki1.make_plan(k)
        p2i = ozaki2.make_plan(k, substrate="int8")
        p2f = ozaki2.make_plan(k, substrate="fp8")
        rows.append((f"volume/ozaki1_gemms/k{k}", 0.0, float(p1.num_gemms)))
        rows.append((f"volume/ozaki2_int8_gemms/k{k}", 0.0, float(p2i.alpha)))
        rows.append((f"volume/ozaki2_fp8_gemms/k{k}", 0.0, float(p2f.alpha)))
    return rows


def emulation_wallclock() -> List[Row]:
    """CPU wall-clock per emulated GEMM (machinery check; TPU is the perf target)."""
    rows: List[Row] = []
    rng = np.random.default_rng(1)
    for n in (128, 256):
        a = jnp.asarray(rng.standard_normal((n, n)))
        b = jnp.asarray(rng.standard_normal((n, n)))
        for name, fn in (
            ("ozaki2_int8", lambda a, b, n=n: ozaki2.emulated_matmul(
                a, b, ozaki2.make_plan(n, substrate="int8"))),
            ("ozaki2_fp8", lambda a, b, n=n: ozaki2.emulated_matmul(
                a, b, ozaki2.make_plan(n, substrate="fp8"))),
            ("ozaki1_int8", lambda a, b: ozaki1.emulated_matmul(a, b)),
            ("native_f64", jnp.matmul),
        ):
            us, _ = _timed(fn, a, b)
            rows.append((f"wallclock_gemm/{name}/n{n}", us, 0.0))
    return rows
