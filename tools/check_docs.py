#!/usr/bin/env python3
"""Docs-freshness check: the seam and its knobs may not outgrow docs/.

Asserts (stdlib only — the CI lint job has no jax installed, so this parses
source text rather than importing repro):

  * every dispatch kind in ``AUTO_ROUTE`` (src/repro/core/dispatch.py)
    appears somewhere under docs/;
  * every ``REPRO_*`` environment variable referenced anywhere under src/
    appears somewhere under docs/.

Exit 0 when fresh; exit 1 listing what is undocumented.  Run from anywhere:
``python tools/check_docs.py``.
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
ENV_RE = re.compile(r"REPRO_[A-Z]+(?:_[A-Z]+)*")


def auto_route_kinds() -> set:
    text = (ROOT / "src" / "repro" / "core" / "dispatch.py").read_text()
    m = re.search(r"^AUTO_ROUTE\s*=\s*\{(.*?)^\}", text, re.S | re.M)
    if not m:
        sys.exit("check_docs: could not locate the AUTO_ROUTE literal in "
                 "src/repro/core/dispatch.py")
    kinds = set(re.findall(r'^\s*"([a-z0-9_]+)"\s*:\s*\{', m.group(1), re.M))
    if not kinds:
        sys.exit("check_docs: AUTO_ROUTE parsed to zero kinds")
    return kinds


def repro_env_vars() -> set:
    found = set()
    for path in (ROOT / "src").rglob("*.py"):
        found.update(ENV_RE.findall(path.read_text()))
    return found


def docs_text() -> str:
    docs = sorted((ROOT / "docs").glob("*.md"))
    if not docs:
        sys.exit("check_docs: docs/ has no markdown pages")
    return "\n".join(p.read_text() for p in docs)


def main() -> int:
    text = docs_text()
    problems = []
    for kind in sorted(auto_route_kinds()):
        # Kinds appear in prose and tables, often inside `code|spans`; a
        # word-boundary search keeps e.g. "gemm" from matching "gemms"-free.
        if not re.search(rf"\b{re.escape(kind)}\b", text):
            problems.append(f"dispatch kind {kind!r} is not mentioned in docs/")
    for var in sorted(repro_env_vars()):
        if var not in text:
            problems.append(f"env var {var} is not mentioned in docs/")
    if problems:
        for p in problems:
            print(f"check_docs: {p}", file=sys.stderr)
        print(f"check_docs: FAILED ({len(problems)} undocumented item(s)) — "
              "update docs/architecture.md / docs/env.md", file=sys.stderr)
        return 1
    print("check_docs: docs/ covers every AUTO_ROUTE kind and REPRO_* knob")
    return 0


if __name__ == "__main__":
    sys.exit(main())
