"""minitron-4b [dense] — pruned nemotron (squared-ReLU MLP).

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000 [arXiv:2407.14679; hf].
24 heads is not divisible by the 16-way model axis — the sharding rules fall back
to head_dim sharding for this arch (DESIGN.md §5).
"""

from repro.configs.base import BlockCfg, ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="decoder",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    pattern=(BlockCfg(mixer="attn", mlp="dense"),),
    mlp_act="relu2",
)

SMOKE_CONFIG = ModelConfig(
    name="minitron-4b-smoke",
    family="decoder",
    num_layers=2,
    d_model=48,
    num_heads=3,
    num_kv_heads=1,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    pattern=(BlockCfg(mixer="attn", mlp="dense"),),
    mlp_act="relu2",
)
