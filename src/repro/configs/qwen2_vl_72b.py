"""qwen2-vl-72b [vlm] — M-RoPE, dynamic-resolution vision frontend stubbed.

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 [arXiv:2409.12191; hf].
The vision frontend is a STUB: input_specs provides precomputed patch embeddings
(B, S, d_model) and the (B, 3, S) M-RoPE position streams.
"""

from repro.configs.base import BlockCfg, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="decoder",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    pattern=(BlockCfg(mixer="attn", mlp="dense"),),
    mlp_act="swiglu",
    rope_type="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    frontend="vision",
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-vl-72b-smoke",
    family="decoder",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    pattern=(BlockCfg(mixer="attn", mlp="dense"),),
    mlp_act="swiglu",
    rope_type="mrope",
    mrope_sections=(2, 3, 3),
    frontend="vision",
)
