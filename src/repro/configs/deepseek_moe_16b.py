"""deepseek-moe-16b [moe] — fine-grained MoE: 2 shared + 64 routed top-6.

28L d_model=2048 16H (GQA kv=16) d_ff=1408 (expert width) vocab=102400
[arXiv:2401.06066; hf].
"""

from repro.configs.base import BlockCfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="decoder",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    pattern=(BlockCfg(mixer="attn", mlp="moe"),),
    mlp_act="swiglu",
    moe=MoECfg(num_experts=64, top_k=6, d_expert=1408, num_shared=2),
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-moe-smoke",
    family="decoder",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=48,
    vocab_size=256,
    pattern=(BlockCfg(mixer="attn", mlp="moe"),),
    mlp_act="swiglu",
    moe=MoECfg(num_experts=8, top_k=3, d_expert=48, num_shared=2),
)
