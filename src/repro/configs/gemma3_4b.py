"""gemma3-4b [dense] — 5:1 local:global sliding-window attention, 128k context.

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144
[hf:google/gemma-3-1b-pt; unverified].  Pattern period 6 (5 local @1024 window +
1 global); 34 = 5·6 + 4 leaves a 4-layer unrolled tail.  The sliding-window
majority is why this arch runs the long_500k decode cell (ring-buffer caches cap
at the window size; only the 6 global layers hold full-length KV).
"""

from repro.configs.base import BlockCfg, ModelConfig

_LOCAL = BlockCfg(mixer="attn", mlp="dense", window=1024)
_GLOBAL = BlockCfg(mixer="attn", mlp="dense", window=0)

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="decoder",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    mlp_act="geglu",
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma3-4b-smoke",
    family="decoder",
    num_layers=8,   # 1 full period (6) + 2-layer tail: exercises both paths
    d_model=64,
    num_heads=2,
    num_kv_heads=1,
    head_dim=32,
    d_ff=128,
    vocab_size=256,
    pattern=(BlockCfg(mixer="attn", mlp="dense", window=8),) * 5
            + (BlockCfg(mixer="attn", mlp="dense", window=0),),
    mlp_act="geglu",
    tie_embeddings=True,
)
