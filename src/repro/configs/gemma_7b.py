"""gemma-7b [dense] — GeGLU, head_dim=256, tied embeddings.

28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000 [arXiv:2403.08295; hf].
"""

from repro.configs.base import BlockCfg, ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="decoder",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    pattern=(BlockCfg(mixer="attn", mlp="dense"),),
    mlp_act="geglu",
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma-7b-smoke",
    family="decoder",
    num_layers=2,
    d_model=64,
    num_heads=2,
    num_kv_heads=2,
    head_dim=32,
    d_ff=192,
    vocab_size=256,
    pattern=(BlockCfg(mixer="attn", mlp="dense"),),
    mlp_act="geglu",
    tie_embeddings=True,
)
