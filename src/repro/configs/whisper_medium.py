"""whisper-medium [audio] — enc-dec, conv frontend stubbed per assignment.

24L (24 enc + 24 dec) d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=51865
[arXiv:2212.04356; unverified].  The audio conv frontend is a STUB: input_specs
provides precomputed frame embeddings (B, 1500, d_model).
"""

from repro.configs.base import BlockCfg, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    pattern=(BlockCfg(mixer="attn", mlp="dense"),),
    mlp_act="geglu",
    encoder_layers=24,
    encoder_seq=1500,
    frontend="audio",
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-medium-smoke",
    family="encdec",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    pattern=(BlockCfg(mixer="attn", mlp="dense"),),
    mlp_act="geglu",
    encoder_layers=2,
    encoder_seq=12,
    frontend="audio",
)
