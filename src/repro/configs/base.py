"""Model / run configuration schema.

A ``ModelConfig`` fully determines an architecture; the 10 assigned architectures
each get a module in this package exporting ``CONFIG`` (full scale, dry-run only)
and ``SMOKE_CONFIG`` (reduced same-family config for CPU tests).

Layer topology is expressed as a repeating ``pattern`` of ``BlockCfg`` entries
(mixer kind + MLP kind + attention window), scanned over ``num_layers // period``
periods with an unrolled tail for non-divisible depths (e.g. gemma3's 34 = 5·6+4).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared: int = 0
    capacity_factor: float = 1.25
    router_zloss: float = 1e-3


@dataclasses.dataclass(frozen=True)
class BlockCfg:
    """One layer's shape: mixer + MLP.

    mixer:  attn | mamba | mlstm | slstm
    mlp:    dense | moe | none
    window: 0 = global attention; >0 = sliding-window size (attn mixers only)
    """
    mixer: str = "attn"
    mlp: str = "dense"
    window: int = 0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # decoder | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[BlockCfg, ...] = (BlockCfg(),)
    mlp_act: str = "swiglu"          # swiglu | geglu (gated; d_ff = hidden width)
    rope_theta: float = 10_000.0
    rope_type: str = "standard"      # standard | mrope | none
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    moe: Optional[MoECfg] = None
    # SSM / xLSTM
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    # encoder-decoder
    encoder_layers: int = 0
    encoder_seq: int = 0             # e.g. whisper: 1500 frames
    frontend: Optional[str] = None   # audio | vision | None (stubs per assignment)
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    policy_name: str = "bf16"        # precision policy for weight matmuls
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    remat: bool = True               # activation checkpointing per period
    force_unroll: bool = False       # python-loop layers (exact HLO cost counting
                                     # — lax.scan bodies are costed once by XLA)
    attn_chunk: int = 1024           # flash-style q-block size (0 = unchunked)
    ssm_chunk: int = 256             # mamba outer time-chunk
    lstm_chunk: int = 64             # xLSTM chunk (bounded-remat working set)

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def num_periods(self) -> int:
        return self.num_layers // self.period

    @property
    def tail_blocks(self) -> Tuple[BlockCfg, ...]:
        rem = self.num_layers % self.period
        return self.pattern[:rem]

    def block_at(self, layer: int) -> BlockCfg:
        return self.pattern[layer % self.period]

    @property
    def compute_jnp_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.compute_dtype]

    @property
    def param_jnp_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.param_dtype]

    @property
    def d_inner(self) -> int:
        """SSM/xLSTM inner width."""
        return self.ssm_expand * self.d_model

    def param_count(self) -> int:
        """Approximate total parameter count (embedding + blocks), for 6ND."""
        d, v = self.d_model, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        for i in range(self.num_layers):
            b = self.block_at(i)
            if b.mixer == "attn":
                total += d * (self.num_heads * self.head_dim) * 2  # q, o
                total += d * (self.num_kv_heads * self.head_dim) * 2  # k, v
            elif b.mixer == "mamba":
                di = self.d_inner
                total += d * di * 3 + di * self.ssm_state_dim * 2 + di * d
            elif b.mixer in ("mlstm", "slstm"):
                di = self.d_inner
                total += d * di * 4 + di * d
            if b.mlp == "dense" and self.d_ff > 0:
                total += 3 * d * self.d_ff
            elif b.mlp == "moe" and self.moe is not None:
                m = self.moe
                total += d * m.num_experts  # router
                total += (m.num_experts + m.num_shared) * 3 * d * m.d_expert
        if self.family == "encdec":
            # encoder blocks + cross-attention in every decoder layer
            enc = self.encoder_layers * (
                d * (self.num_heads * self.head_dim) * 2
                + d * (self.num_kv_heads * self.head_dim) * 2 + 3 * d * self.d_ff)
            cross = self.num_layers * (
                d * (self.num_heads * self.head_dim) * 2
                + d * (self.num_kv_heads * self.head_dim) * 2)
            total += enc + cross
        return total

    def active_param_count(self) -> int:
        """Active (per-token) parameters — the MoE-aware N for MODEL_FLOPS=6ND."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        total = self.param_count()
        # subtract inactive routed experts
        for i in range(self.num_layers):
            if self.block_at(i).mlp == "moe":
                inactive = (m.num_experts - m.top_k)
                total -= inactive * 3 * self.d_model * m.d_expert
        return total


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
