"""Architecture registry: ``--arch <id>`` resolution, shape cells, input specs.

``runnable_cells()`` enumerates every (arch × shape) dry-run cell, applying the
assignment's skip rules:
  * long_500k needs sub-quadratic attention — skipped for pure full-attention
    archs (whisper, qwen2-vl, minitron, yi, gemma-7b, deepseek-moe, llama4-scout);
    run for gemma3 (5:1 local), jamba (hybrid SSM), xlstm (SSM).
  * none of the assigned archs is encoder-only, so no decode-shape skips.
"""

from __future__ import annotations

import importlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES_BY_NAME as SHAPES_BY_NAME  # re-export
from repro.configs.base import SHAPES, ModelConfig, ShapeSpec

ARCHS: Dict[str, str] = {
    "whisper-medium": "repro.configs.whisper_medium",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "minitron-4b": "repro.configs.minitron_4b",
    "yi-6b": "repro.configs.yi_6b",
    "gemma-7b": "repro.configs.gemma_7b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout",
    "xlstm-350m": "repro.configs.xlstm_350m",
}

# Archs whose sequence mixing is sub-quadratic (SSM / hybrid / sliding-window
# majority) — the only ones that run the long_500k cell.
SUBQUADRATIC = ("gemma3-4b", "jamba-1.5-large-398b", "xlstm-350m")


def get_config(arch: str, smoke: bool = False, **overrides) -> ModelConfig:
    mod = importlib.import_module(ARCHS[arch])
    cfg = mod.SMOKE_CONFIG if smoke else mod.CONFIG
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def list_archs() -> List[str]:
    return list(ARCHS)


def cell_is_runnable(arch: str, shape: ShapeSpec) -> Tuple[bool, str]:
    if shape.name == "long_500k" and arch not in SUBQUADRATIC:
        return False, "long_500k skipped: pure full-attention arch (see DESIGN.md)"
    return True, ""


def runnable_cells() -> List[Tuple[str, ShapeSpec]]:
    out = []
    for arch in ARCHS:
        for shape in SHAPES:
            ok, _ = cell_is_runnable(arch, shape)
            if ok:
                out.append((arch, shape))
    return out


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation — dry-run contract)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                batch_override: Optional[int] = None) -> Dict:
    """Shape/dtype stand-ins for every model input of this (arch × shape) cell.

    train/prefill: token (or stub-frontend embedding) batch + labels;
    decode: one new token + position (the KV/state cache is constructed
    separately by ``cache_specs`` since it is carried state, not input).
    """
    B = batch_override or shape.global_batch
    S = shape.seq_len
    f = jnp.bfloat16
    if shape.kind in ("train", "prefill"):
        specs: Dict = {}
        if cfg.frontend == "vision":
            # patch embeddings from the stub frontend + M-RoPE position streams
            specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), f)
            specs["positions"] = jax.ShapeDtypeStruct((B, 3, S), jnp.int32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.family == "encdec":
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), f)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return specs
    # decode: one token against a seq_len-deep cache
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def concrete_batch(cfg: ModelConfig, shape: ShapeSpec, batch: int,
                   seq: Optional[int] = None, seed: int = 0) -> Dict:
    """Small *concrete* batch for smoke tests (reduced configs only)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    S = seq or min(shape.seq_len, 32)
    out: Dict = {}
    if cfg.frontend == "vision":
        out["embeds"] = jnp.asarray(
            rng.standard_normal((batch, S, cfg.d_model)), jnp.bfloat16)
        pos = np.broadcast_to(np.arange(S), (batch, 3, S))
        out["positions"] = jnp.asarray(pos.copy(), jnp.int32)
    else:
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, S)), jnp.int32)
    if cfg.family == "encdec":
        out["enc_embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.encoder_seq, cfg.d_model)),
            jnp.bfloat16)
    out["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, S)), jnp.int32)
    return out
