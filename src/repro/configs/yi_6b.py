"""yi-6b [dense] — llama-arch GQA.

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 [arXiv:2403.04652; hf].
"""

from repro.configs.base import BlockCfg, ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="decoder",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    pattern=(BlockCfg(mixer="attn", mlp="dense"),),
    mlp_act="swiglu",
    rope_theta=5_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="yi-6b-smoke",
    family="decoder",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    pattern=(BlockCfg(mixer="attn", mlp="dense"),),
    mlp_act="swiglu",
)
