"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2
[arXiv:2403.19887; hf].  Period-8 pattern: 1 attention + 7 Mamba layers; MoE MLP
every second layer (the Jamba recipe).  ~398B total / ~94B active parameters.
Hybrid SSM majority => runs the long_500k decode cell (state is O(1) in seq).
"""

from repro.configs.base import BlockCfg, ModelConfig, MoECfg

def _blk(mixer: str, idx: int) -> BlockCfg:
    return BlockCfg(mixer=mixer, mlp="moe" if idx % 2 == 1 else "dense")

_PATTERN = tuple(
    _blk("attn" if j == 0 else "mamba", j) for j in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="decoder",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    pattern=_PATTERN,
    mlp_act="swiglu",
    moe=MoECfg(num_experts=16, top_k=2, d_expert=24576),
    ssm_state_dim=16,
    ssm_expand=2,
    rope_type="none",          # jamba uses no positional encoding in attn layers
)

SMOKE_CONFIG = ModelConfig(
    name="jamba-smoke",
    family="decoder",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    pattern=(BlockCfg(mixer="attn", mlp="dense"),
             BlockCfg(mixer="mamba", mlp="moe"),
             BlockCfg(mixer="mamba", mlp="dense"),
             BlockCfg(mixer="mamba", mlp="moe")),
    mlp_act="swiglu",
    moe=MoECfg(num_experts=4, top_k=2, d_expert=96),
    ssm_state_dim=4,
    ssm_expand=2,
    rope_type="none",
)
