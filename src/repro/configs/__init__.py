"""repro.configs subpackage."""
