"""xlstm-350m [ssm] — alternating sLSTM + mLSTM blocks, attention-free.

24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304 [arXiv:2405.04517; unverified].
d_ff = 0: the xLSTM blocks carry their own up/down projections (expand factor 2),
so the MLP slot is "none".  Attention-free => runs the long_500k decode cell.
"""

from repro.configs.base import BlockCfg, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="decoder",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    pattern=(BlockCfg(mixer="mlstm", mlp="none"),
             BlockCfg(mixer="slstm", mlp="none")),
    rope_type="none",
    ssm_expand=2,
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="xlstm-smoke",
    family="decoder",
    num_layers=4,
    d_model=64,
    num_heads=2,
    num_kv_heads=2,
    head_dim=32,
    d_ff=0,
    vocab_size=256,
    pattern=(BlockCfg(mixer="mlstm", mlp="none"),
             BlockCfg(mixer="slstm", mlp="none")),
    rope_type="none",
    ssm_expand=2,
    tie_embeddings=True,
)
