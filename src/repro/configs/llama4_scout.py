"""llama4-scout-17b-a16e [moe] — MoE 16 experts top-1 + shared expert.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].  Early fusion is a frontend
concern and is stubbed per the assignment (text path lowered).  40 heads is not
divisible by the 16-way model axis — head_dim sharding fallback (DESIGN.md §5).
"""

from repro.configs.base import BlockCfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="decoder",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    pattern=(BlockCfg(mixer="attn", mlp="moe"),),
    mlp_act="swiglu",
    moe=MoECfg(num_experts=16, top_k=1, d_expert=8192, num_shared=1),
    rope_theta=500_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="llama4-scout-smoke",
    family="decoder",
    num_layers=2,
    d_model=64,
    num_heads=5,
    num_kv_heads=1,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    pattern=(BlockCfg(mixer="attn", mlp="moe"),),
    mlp_act="swiglu",
    moe=MoECfg(num_experts=4, top_k=1, d_expert=96, num_shared=1),
)
