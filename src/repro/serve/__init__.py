"""repro.serve subpackage."""
