"""Serving engine: prefill + decode with continuous batching.

``ServeEngine`` wraps a Model with:
  * ``prefill``  — full-sequence forward that also populates the KV/state cache
    (teacher-forced pass over the prompt, cache written via decode steps in
    chunks for state mixers);
  * ``decode``   — batched single-token steps (the shape lowered by decode
    cells in the dry-run);
  * ``ContinuousBatcher`` — slot-based request scheduler: finished sequences
    release their cache slot to queued requests between steps (the vLLM-style
    loop, with per-slot position counters).

With ``REPRO_TELEMETRY`` on (``repro.obs.telemetry``), the engine records
per-step serving events: ``serve.prefill`` (wall μs + tokens/sec per prompt),
``serve.decode`` (wall μs + tokens/sec per batched step), and
``serve.queue`` (queue depth / active slots per scheduler step) — alongside
the per-matmul seam events the model's dispatch calls record on their own.

Under an emulated precision policy (``policy_name="ozaki2_*"``), the score
path of every prefill and decode step rides the dispatch seam's fused
``attention`` kind (``dispatch.attention``: FlashAttention-style Pallas scan
vs bit-identical reference), so the engine's ``dispatch_mode`` pin flips the
serving hot path between the fused kernel and the reference exactly like the
weight matmuls — and telemetry distinguishes the two serving shape classes
via the kind's ``prefill`` / ``decode`` labels.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.models.transformer import Model
from repro.obs import telemetry as obs

Pytree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray             # (P,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class ServeEngine:
    def __init__(self, model: Model, params: Pytree, batch_slots: int,
                 max_seq: int, dispatch_mode: Optional[str] = None):
        """``dispatch_mode`` pins the emulation dispatch route (auto | xla |
        pallas) for every matmul this engine traces, so serving picks up the
        fused Pallas path with no model-code changes; None inherits the
        ambient ``REPRO_DISPATCH`` setting."""
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.dispatch_mode = dispatch_mode
        self.cache = model.init_cache(batch_slots, max_seq)
        self.pos = np.zeros(batch_slots, np.int32)
        self._decode = jax.jit(model.decode_step)

    def _decode_call(self, *args):
        with dispatch.mode_scope(self.dispatch_mode):
            return self._decode(*args)

    def prefill_slot(self, slot: int, prompt: np.ndarray) -> int:
        """Feed a prompt through decode steps to fill the cache slot.

        Single-slot prefill via the decode path keeps cache semantics identical
        for every mixer kind (attention ring buffers and SSM states alike).
        """
        t0 = time.perf_counter() if obs.enabled() else None
        last = 0
        for t, tok in enumerate(prompt):
            tokens = np.zeros((self.slots, 1), np.int32)
            tokens[slot, 0] = tok
            logits, self.cache = self._decode_call(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(t, jnp.int32))
            last = int(jnp.argmax(logits[slot, 0]))
        self.pos[slot] = len(prompt)
        if t0 is not None:
            dt = time.perf_counter() - t0
            obs.record_event("serve.prefill", us=dt * 1e6,
                             route=self.dispatch_mode or "",
                             tokens=len(prompt), slot=slot,
                             tokens_per_s=len(prompt) / max(dt, 1e-9))
        return last

    def decode_step_all(self, tokens: np.ndarray, pos: int) -> np.ndarray:
        t0 = time.perf_counter() if obs.enabled() else None
        logits, self.cache = self._decode_call(
            self.params, self.cache, jnp.asarray(tokens.reshape(-1, 1)),
            jnp.asarray(pos, jnp.int32))
        out = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        if t0 is not None:
            dt = time.perf_counter() - t0
            obs.record_event("serve.decode", us=dt * 1e6,
                             route=self.dispatch_mode or "",
                             batch=self.slots,
                             tokens_per_s=self.slots / max(dt, 1e-9))
        return out


@dataclasses.dataclass
class ContinuousBatcher:
    """Slot scheduler: admits queued requests into freed slots each step."""
    engine: ServeEngine
    queue: List[Request] = dataclasses.field(default_factory=list)
    active: Dict[int, Request] = dataclasses.field(default_factory=dict)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.engine.slots):
            if slot not in self.active and self.queue:
                req = self.queue.pop(0)
                first = self.engine.prefill_slot(slot, req.prompt)
                req.generated.append(first)
                self.active[slot] = req

    def step(self) -> List[Request]:
        """One engine step; returns requests that finished this step."""
        obs.record_event("serve.queue", queued=len(self.queue),
                         active=len(self.active))
        self._admit()
        if not self.active:
            return []
        tokens = np.zeros(self.engine.slots, np.int32)
        pos = 0
        for slot, req in self.active.items():
            tokens[slot] = req.generated[-1]
            pos = max(pos, int(self.engine.pos[slot]))
        nxt = self.engine.decode_step_all(tokens, pos)
        finished = []
        for slot, req in list(self.active.items()):
            req.generated.append(int(nxt[slot]))
            self.engine.pos[slot] += 1
            if req.done:
                finished.append(req)
                del self.active[slot]      # slot released -> next admit() reuses
        return finished

    def run_to_completion(self, max_steps: int = 1000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and not self.active:
                break
            done.extend(self.step())
        return done
