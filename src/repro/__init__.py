"""FP8-is-all-you-need reproduction: Ozaki-scheme FP64 emulation in JAX/Pallas."""
