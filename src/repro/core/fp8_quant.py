"""FP8 (E4M3) exact-integer quantisation helpers for the Ozaki-II FP8 substrate.

Paper §2.4: modular reduction is an integer operation, so running Ozaki II on FP8
tensor cores needs the Uchino/Ozaki/Imamura quantisation trick — exploit the set of
integers that E4M3 represents *exactly* (all |x| with <= 4 significand bits; in
particular every integer |x| <= 16) and split each balanced residue into two exact
4-bit halves.  The product of two residues is then reassembled from three FP8 MMAs
(Karatsuba), giving the (3r+·) FP8 cost structure the paper describes.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def is_exact_e4m3(x: int) -> bool:
    """True iff integer x is exactly representable in float8_e4m3fn."""
    return float(np.asarray(float(x), np.float8_e4m3fn).astype(np.float64)) == float(x)


def fp8_split(res: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Split balanced int8 residues (|res| <= 128) into exact E4M3 halves.

    res = 16*hi + lo with |hi| <= 8, |lo| <= 8; hi, lo, and hi+lo (|.| <= 16) are all
    exactly representable in E4M3, which is what makes the Karatsuba mid-plane
    (x_h+x_l)(y_h+y_l) exact on the FP8 engine.
    """
    r32 = res.astype(jnp.int32)
    hi = jnp.round(r32.astype(jnp.float32) / 16.0).astype(jnp.int32)
    lo = r32 - 16 * hi
    return hi, lo


def fp8_karatsuba_combine(H: jax.Array, Mid: jax.Array, L: jax.Array,
                          m: int) -> jax.Array:
    """Recombine the three Karatsuba planes mod m (balanced int32 in, balanced out).

    x·y = 256·H + 16·(Mid − H − L) + L.  Planes are reduced mod m before
    recombination so all int32 intermediates stay < 2**17.
    """
    def bal(v):
        u = jnp.remainder(v, m)
        return jnp.where(u > (m - 1) // 2, u - m, u)

    Hm, Lm, Midm = bal(H), bal(L), bal(Mid)
    return bal((256 % m) * Hm + (16 % m) * (Midm - Hm - Lm) + Lm)
