"""Ozaki Scheme II — CRT/residue FP64 matrix-multiplication emulation (paper §2.3–§2.4).

Pipeline (paper Phases 1–3):
  1. ``scale_to_int``  : Ã = ⌊D A⌉, B̃ = ⌊B E⌉ with exact power-of-two diagonal scaling.
  2. ``modular_matmul``: C⁽ⁱ⁾ = (Ã mod mᵢ)(B̃ mod mᵢ) mod mᵢ for r pairwise-coprime
     moduli.  INT8 substrate: int8 dot with int32 accumulation (the TPU MXU int8 path,
     standing in for the paper's INT8 tensor cores).  FP8 substrate: the Uchino-style
     quantisation trick of §2.4 — each balanced residue is split into two exact 4-bit
     E4M3 halves and multiplied with a Karatsuba 3-MMA schedule, FP32 accumulation;
     exactness is guaranteed by construction (all partial sums are integers < 2²⁴).
  3. ``garner_reconstruct``: balanced-digit Garner mixed-radix reconstruction (paper
     eq. (7), Appendix A), followed by the exact power-of-two unscale D^{-1}·E^{-1}.

Everything is pure JAX (jit/vmap/grad-safe, no Python-level data dependence), with the
moduli plan as a static argument.  The Pallas kernels in ``repro.kernels`` implement the
*fused* version of the same arithmetic (β = 1 discipline); this module is the
mathematical reference and the XLA fallback path used by the precision policy.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import fp8_quant
from repro.core import moduli as moduli_lib
from repro.core import splitting

Substrate = str  # "int8" | "fp8"

# int32 accumulation of balanced int8 residue products (|v| <= 128) is exact for
# k <= 2**31 / 128**2; chunk the contraction above this.
_INT8_K_CHUNK = 1 << 17
# fp8 path: per-plane integer products <= 16**2; fp32 accumulation exact below 2**24.
_FP8_K_CHUNK = 1 << 16


@dataclasses.dataclass(frozen=True)
class Plan:
    """Static Ozaki-II configuration (hashable; used as a jit static argument)."""

    moduli: Tuple[int, ...]
    payload_bits: int            # p: |Ã| < 2**p
    substrate: Substrate = "int8"

    @property
    def r(self) -> int:
        return len(self.moduli)

    @functools.cached_property
    def garner(self) -> moduli_lib.GarnerConstants:
        # cached_property writes through the instance __dict__, which frozen
        # dataclasses permit; hash/eq still come from the declared fields.
        return moduli_lib.garner_constants(self.moduli)

    @property
    def alpha(self) -> int:
        """TME compute multiplier α: low-precision MMAs per FP64 op (paper Def. 1).

        INT8: r modular GEMMs.  FP8: 3r (Karatsuba hi/lo planes, §2.4's (3r+1) without
        the +1 correction GEMM, which our exact-by-construction split does not need).
        """
        return self.r if self.substrate == "int8" else 3 * self.r


def make_plan(k: int, payload_bits: int = 53, r: Optional[int] = None,
              substrate: Substrate = "int8", margin_bits: int = 2) -> Plan:
    """Build a Plan for contractions of length k.

    If ``r`` is given, the payload is clipped to what those r moduli support at this k
    (paper §2.4 sensitivity analysis); otherwise r is the minimum for ``payload_bits``.
    """
    if r is None:
        r = moduli_lib.required_r(k, payload_bits, margin_bits)
    else:
        payload_bits = min(payload_bits,
                           moduli_lib.max_payload_bits(r, k, margin_bits))
    return Plan(moduli=moduli_lib.DEFAULT_MODULI[:r], payload_bits=payload_bits,
                substrate=substrate)


# ---------------------------------------------------------------------------
# Phase 1+: decomposition to residues
# ---------------------------------------------------------------------------

def decompose(x: jax.Array, plan: Plan, scale_axis: int,
              via_hilo: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Residue decomposition: returns (residues int8 (r, *x.shape), shift int32).

    ``scale_axis`` is the contraction axis (the axis along which the max-magnitude
    scaling of Appendix C is taken): rows of A scale over axis=-1, columns of B over
    axis=0.  ``via_hilo`` selects the TPU-native int32 (hi,lo) residue path (default)
    versus the int64 oracle (CPU tests only).
    """
    xi, shift = splitting.scale_to_int(x, plan.payload_bits, axis=scale_axis)
    if via_hilo:
        hi, lo = splitting.split_hi_lo(xi)
        res = splitting.residues_from_hilo(hi, lo, plan.moduli)
    else:
        res = splitting.residues_direct(xi, plan.moduli)
    return res, shift


# ---------------------------------------------------------------------------
# Phase 2: modular matmuls
# ---------------------------------------------------------------------------

def _balanced_mod_i32(v: jax.Array, m: int) -> jax.Array:
    u = jnp.remainder(v, m)
    return jnp.where(u > (m - 1) // 2, u - m, u)


def _dot_int8(a8: jax.Array, b8: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 contraction over the last/first axes (MXU int8 path)."""
    return jax.lax.dot_general(
        a8, b8, (((a8.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def _chunked_modular_dot_int8(ares: jax.Array, bres: jax.Array, m: int) -> jax.Array:
    """(Ã mod m)(B̃ mod m) mod m with int32-safe chunking over the contraction."""
    k = ares.shape[-1]
    if k <= _INT8_K_CHUNK:
        return _balanced_mod_i32(_dot_int8(ares, bres), m)
    acc = None
    for s in range(0, k, _INT8_K_CHUNK):
        e = min(s + _INT8_K_CHUNK, k)
        part = _balanced_mod_i32(_dot_int8(ares[..., s:e], bres[s:e]), m)
        acc = part if acc is None else _balanced_mod_i32(acc + part, m)
    return acc


def _dot_fp8(a: jax.Array, b: jax.Array) -> jax.Array:
    """float8_e4m3fn x float8_e4m3fn -> float32 contraction (FP8 tensor-core path)."""
    a8 = a.astype(jnp.float8_e4m3fn)
    b8 = b.astype(jnp.float8_e4m3fn)
    return jax.lax.dot_general(
        a8, b8, (((a8.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _chunked_modular_dot_fp8(ares: jax.Array, bres: jax.Array, m: int) -> jax.Array:
    """FP8-substrate modular product (paper §2.4): Karatsuba over 4-bit halves.

    x·y = 256·H + 16·(Mid − H − L) + L with H = x_h·y_h, L = x_l·y_l,
    Mid = (x_h+x_l)·(y_h+y_l).  Each plane accumulates exactly in FP32 (integer sums
    < 2²⁴ for k <= 2¹⁶); planes are reduced mod m *before* recombination so all int32
    arithmetic stays tiny.
    """
    k = ares.shape[-1]
    a_hi, a_lo = fp8_quant.fp8_split(ares)
    b_hi, b_lo = fp8_quant.fp8_split(bres)

    def plane(asrc, bsrc, s, e):
        return _dot_fp8(asrc[..., s:e].astype(jnp.float32),
                        bsrc[s:e].astype(jnp.float32))

    acc = None
    for s in range(0, k, _FP8_K_CHUNK):
        e = min(s + _FP8_K_CHUNK, k)
        H = plane(a_hi, b_hi, s, e).astype(jnp.int32)
        L = plane(a_lo, b_lo, s, e).astype(jnp.int32)
        Mid = plane(a_hi + a_lo, b_hi + b_lo, s, e).astype(jnp.int32)
        part = fp8_quant.fp8_karatsuba_combine(H, Mid, L, m)
        acc = part if acc is None else _balanced_mod_i32(acc + part, m)
    return acc


def modular_matmul(ares: jax.Array, bres: jax.Array, plan: Plan) -> jax.Array:
    """Stacked modular products C⁽ⁱ⁾, int32 (r, m, n), balanced representatives."""
    fn = (_chunked_modular_dot_int8 if plan.substrate == "int8"
          else _chunked_modular_dot_fp8)
    outs = [fn(ares[i], bres[i], m) for i, m in enumerate(plan.moduli)]
    return jnp.stack(outs, axis=0)


# ---------------------------------------------------------------------------
# Phase 3: Garner reconstruction
# ---------------------------------------------------------------------------

def garner_reconstruct(cres: jax.Array, plan: Plan,
                       out_dtype=jnp.float64) -> jax.Array:
    """Balanced-digit Garner: recover the (signed) integer value as a float.

    cres: int32 (r, ...) balanced residues of the exact integer product.
    Cost O(r²) elementwise ops — the TME γ term; amortised O(r²/k) per FMA.

    Balanced digits make the representation of |C| << M terminate: digits beyond
    ~log2(2|C|) bits are exactly zero, so only prefix products comparable to |C|
    enter the float sum.  The accumulation runs in compensated double-double
    arithmetic with exact double-double prefix-product constants, so the returned
    value is the *correctly rounded* float of the exact integer: products whose
    unscaled value is representable in the output mantissa are recovered EXACTLY.
    """
    from repro.core import numerics

    gc = plan.garner
    r = plan.r
    ms = plan.moduli
    acc = [jnp.zeros(cres.shape[1:], jnp.int32) for _ in range(r)]
    out = jnp.zeros(cres.shape[1:], out_dtype)
    comp = jnp.zeros(cres.shape[1:], out_dtype)
    for j in range(r):
        t = _balanced_mod_i32(
            (cres[j].astype(jnp.int32) - acc[j]) * int(gc.inv_pref[j]), ms[j])
        tf = t.astype(out_dtype)
        # term = t * P_j in double-double: P_j = pref_f64 + pref_f64_lo (exact).
        p_term, e_term = numerics.two_prod(
            tf, jnp.asarray(gc.pref_f64[j], out_dtype))
        e_term = e_term + tf * jnp.asarray(gc.pref_f64_lo[j], out_dtype)
        s, e_sum = numerics.two_sum(out, p_term)
        comp = comp + (e_sum + e_term)
        out = s
        for l in range(j + 1, r):
            acc[l] = _balanced_mod_i32(acc[l] + t * int(gc.pref_mod[j, l]), ms[l])
    return out + comp


# ---------------------------------------------------------------------------
# End-to-end emulated matmul
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("plan", "via_hilo", "out_dtype"))
def emulated_matmul(a: jax.Array, b: jax.Array, plan: Plan,
                    via_hilo: bool = True, out_dtype=jnp.float64) -> jax.Array:
    """FP64-accurate C = A @ B via Ozaki Scheme II on a low-precision substrate.

    a: (m, k), b: (k, n); float inputs (float64 for full FP64 emulation; float32
    inputs also work with payload clipped to 24 bits).
    """
    a = a.astype(out_dtype)
    b = b.astype(out_dtype)
    ares, ashift = decompose(a, plan, scale_axis=-1, via_hilo=via_hilo)
    bres, bshift = decompose(b, plan, scale_axis=0, via_hilo=via_hilo)
    cres = modular_matmul(ares, bres, plan)
    c_int = garner_reconstruct(cres, plan, out_dtype=out_dtype)
    return splitting.apply_unscale(c_int, ashift, bshift)


def emulated_matmul_batched(a: jax.Array, b: jax.Array, plan: Plan,
                            **kw) -> jax.Array:
    """vmap wrapper for (..., m, k) x (..., k, n) batched emulated matmuls."""
    if a.ndim == 2 and b.ndim == 2:
        return emulated_matmul(a, b, plan, **kw)
    fn = functools.partial(emulated_matmul, plan=plan, **kw)
    for _ in range(max(a.ndim, b.ndim) - 2):
        fn = jax.vmap(fn)
    return fn(a, b)
