"""Compensated reductions — the paper's BLAS-1 closure (§7.1(a) + companion FFT).

The dwarf audit routes BLAS-1 (ddot, dnrm2, CG residuals, FFT scalings) onto
the healthy low-precision vector pipe with error-free-transformation
compensation instead of Ozaki emulation.  This module is the canonical home of
those reductions; the error-free transformations themselves (``two_sum``,
``two_prod``, ``fast_two_sum``) live in ``repro.core.numerics`` and are
re-exported here.

Blocked EFT execution
---------------------
Error-free transformations are blockwise-associative: applying ``two_sum`` in
*any* order and accumulating every discarded rounding term in a plain
compensation stream yields the same Sum2/Dot2 error bound, because each
``two_sum``/``two_prod`` is exact and only the compensation stream (already
O(u)·magnitude) is summed in working precision.  The fast path exploits this:

  1. the operand is zero-padded (exact: ``two_sum(s, 0) = (s, 0)``) and
     reshaped to ``(nblocks, block)`` with ``block`` ~256–1024 lanes from the
     dispatch autotuning table (``repro.core.dispatch.reduce_block``);
  2. within each block, a pairwise ``two_sum`` tree (``log2(block)`` lane-wise
     vector steps, vmapped over all blocks at once) produces per-block partials
     ``(s_b, c_b)``;
  3. a short carry-propagating ``lax.scan`` over the ``nblocks`` partials
     (n/block steps, e.g. 8 for n=4096) folds them with ``two_sum``, feeding
     the carries into the compensation stream;
  4. the result is ``s + c`` — identical math to the element-wise scan, at
     vector-pipe cost, and the whole pipeline is jitted per (shape, block).

Error bound: every product error (``two_prod``) and every summation rounding
(``two_sum``) is captured exactly; only their *sum* rounds.  For ``n`` terms in
precision ``u`` this gives the Ogita-Rump Dot2/Sum2 bound

    |result − exact| ≤ u·|exact| + O(u²)·cond,

where cond = Σ|x_i·y_i| / |Σ x_i·y_i| — twice-working-precision for any
blocking, which is what licenses the blocked evaluation order.  The element
-wise ``lax.scan`` forms are retained as ``*_scan`` references (the parity
oracle in tests/test_compensated.py asserts ≤ 1 ulp agreement).

Provided reductions (working-dtype in/out, ``axis``-aware/batched):
  * ``neumaier_sum``     — compensated summation.  The blocked form uses the
    full Knuth ``two_sum`` EFT, which captures the rounding error exactly for
    *either* magnitude ordering — at least as accurate as the Kahan-Babuska-
    Neumaier case split it replaces (|error| <= 2u·Σ|x| + O(u²));
  * ``compensated_dot``  — Ogita-Rump Dot2: ``two_prod`` each term, ``two_sum``
    the accumulation, carry both error streams — ~twice-working-precision;
  * ``compensated_norm`` — overflow/underflow-safe 2-norm: exact power-of-two
    pre-scaling derived from IEEE bit fields (never the roundable
    ``2.0 ** floor(log2 absmax)``), then a compensated sum of exact
    squared-term pairs.  XLA CPU arithmetic runs flush-to-zero/
    denormals-are-zero — ``jnp.frexp`` misdecodes denormals and any
    mul/div with a denormal operand yields 0 — so the scaling decomposes
    ``|x| = m * 2**e`` via ``lax.bitcast_convert_type`` (bit ops are immune
    to FTZ/DAZ) and denormal *results* are stored by integer-rounding the
    significand and bitcasting it back.  Non-finite semantics are explicit
    and match ``np.linalg.norm``: any NaN → NaN, else any ±inf → +inf.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.numerics import fast_two_sum, two_prod, two_sum  # noqa: F401
from repro.obs import telemetry as obs

__all__ = ["two_sum", "two_prod", "fast_two_sum", "neumaier_sum",
           "compensated_dot", "compensated_norm", "neumaier_sum_scan",
           "compensated_dot_scan"]


# ---------------------------------------------------------------------------
# Blocked fast path
# ---------------------------------------------------------------------------

def _resolve_block(n: int, block: Optional[int]) -> int:
    if block is None:
        from repro.core import dispatch  # deferred: dispatch does not import us
        block = dispatch.reduce_block(n)
    return max(1, min(int(block), n))


def _pad_to_blocks(x: jax.Array, block: int) -> jax.Array:
    """Zero-pad the last axis to a block multiple (exact for sum and dot)."""
    pad = (-x.shape[-1]) % block
    if pad:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        x = jnp.pad(x, widths)
    return x


def _block_tree(p: jax.Array, c: jax.Array):
    """Pairwise two_sum tree over the last axis (lane-wise, all blocks at
    once).  Returns per-block partials (s_b, c_b); every discarded rounding
    term lands in the compensation stream c_b."""
    while p.shape[-1] > 1:
        if p.shape[-1] % 2:                  # odd width: add a zero lane (exact)
            zero = jnp.zeros(p.shape[:-1] + (1,), p.dtype)
            p = jnp.concatenate([p, zero], axis=-1)
            c = jnp.concatenate([c, zero], axis=-1)
        s, e = two_sum(p[..., 0::2], p[..., 1::2])
        c = c[..., 0::2] + c[..., 1::2] + e
        p = s
    return p[..., 0], c[..., 0]


def _carry_scan(s_b: jax.Array, c_b: jax.Array) -> jax.Array:
    """Short carry-propagating scan over per-block partials (leading axis)."""
    def step(carry, inp):
        s, c = carry
        sb, cb = inp
        s, e = two_sum(s, sb)
        return (s, c + (e + cb)), None

    zero = jnp.zeros_like(s_b[0])
    (s, c), _ = jax.lax.scan(step, (zero, zero), (s_b, c_b))
    return s + c


@partial(jax.jit, static_argnames=("block",))
def _blocked_sum2(p: jax.Array, e: jax.Array, block: int) -> jax.Array:
    """Compensated sum of p (+ pre-existing error stream e) along the last
    axis: block tree → per-block partials → carry scan."""
    p = _pad_to_blocks(p, block)
    e = _pad_to_blocks(e, block)
    nb = p.shape[-1] // block
    shape = p.shape[:-1] + (nb, block)
    s_b, c_b = _block_tree(p.reshape(shape), e.reshape(shape))
    # scan wants the block axis leading; batch dims ride along.
    return _carry_scan(jnp.moveaxis(s_b, -1, 0), jnp.moveaxis(c_b, -1, 0))


def _normalize_axis(axis: int, ndim: int) -> int:
    if not -ndim <= axis < ndim:
        raise ValueError(f"axis {axis} out of range for ndim {ndim}")
    return axis % ndim


# ---------------------------------------------------------------------------
# Public reductions — blocked fast path
# ---------------------------------------------------------------------------

def neumaier_sum(x: jax.Array, axis: int = -1,
                 block: Optional[int] = None) -> jax.Array:
    """Compensated (twice-working-precision) sum along ``axis``.

    Jitted blocked EFT (see module docstring); ``block`` defaults to the
    dispatch autotuning table's choice for this length.  Batched: all other
    axes are preserved.
    """
    x = jnp.asarray(x)
    x = jnp.moveaxis(x, _normalize_axis(axis, x.ndim), -1)
    rec = obs.op_start("reduce", (x.shape[-1],), "xla", None, x, label="sum2")
    out = _blocked_sum2(x, jnp.zeros_like(x), _resolve_block(x.shape[-1], block))
    return obs.op_end(rec, out)


def _dot_impl(x: jax.Array, y: jax.Array, axis: int,
              block: Optional[int]) -> jax.Array:
    """Blocked Dot2 body, shared by ``compensated_dot`` (which records a
    telemetry event) and ``compensated_norm`` (which records its own — one
    event per public call, not one per internal reduction)."""
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    if x.shape != y.shape:
        raise ValueError(f"operand shapes differ: {x.shape} vs {y.shape}")
    ax = _normalize_axis(axis, x.ndim)
    x = jnp.moveaxis(x, ax, -1)
    y = jnp.moveaxis(y, ax, -1)
    p, e = two_prod(x, y)
    return _blocked_sum2(p, e, _resolve_block(x.shape[-1], block))


def compensated_dot(x: jax.Array, y: jax.Array, axis: int = -1,
                    block: Optional[int] = None) -> jax.Array:
    """Ogita-Rump Dot2 inner product: ~twice-working-precision accuracy.

    Every elementwise product is split exactly with ``two_prod`` and the
    accumulation carries the ``two_sum`` rounding errors, so the result error
    is O(u²·cond) — in FP32 this is the §7.1(a) "FP32 pipe + compensation"
    BLAS-1 path at ~2^-48 effective accuracy.  ``axis`` selects the reduction
    axis (batched over the rest); operands must have matching shapes.
    """
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    rec = obs.op_start("reduce", (x.shape[_normalize_axis(axis, x.ndim)],),
                       "xla", None, x, y, label="dot2")
    return obs.op_end(rec, _dot_impl(x, y, axis, block))


# IEEE-754 layouts: dtype -> (bit-int dtype, mantissa bits, exponent bias,
# exponent width).  Used for FTZ/DAZ-immune exact scaling in compensated_norm.
_IEEE = {
    jnp.dtype(jnp.float32): (jnp.int32, 23, 127, 8),
    jnp.dtype(jnp.float64): (jnp.int64, 52, 1023, 11),
}


def _ieee_layout(dtype):
    try:
        return _IEEE[jnp.dtype(dtype)]
    except KeyError:
        raise TypeError(
            f"compensated_norm: unsupported dtype {jnp.dtype(dtype)}"
        ) from None


def _pow2(p: jax.Array, dtype) -> jax.Array:
    """Exact power of two ``2**p`` built from bit fields (clamped to the
    normal range, so multiplying by it never hands DAZ a denormal operand)."""
    it, mb, eb, _ = _ieee_layout(dtype)
    p = jnp.clip(p, 1 - eb, eb)
    return jax.lax.bitcast_convert_type((p + eb).astype(it) << mb, dtype)


def _decompose(x: jax.Array):
    """Exact ``|x| = m * 2**e`` from IEEE bit fields: ``m`` an integer-valued
    float in ``[0, 2**(mb+1))``, ``e`` an int32 exponent.

    Bit operations are immune to flush-to-zero/denormals-are-zero, so this is
    exact for denormal inputs — which XLA CPU arithmetic (``jnp.frexp``,
    mul/div) otherwise treats as zero.
    """
    it, mb, eb, ew = _ieee_layout(x.dtype)
    bits = jax.lax.bitcast_convert_type(x, it)
    bits = bits & ((1 << (mb + ew)) - 1)          # clear the sign bit
    expf = (bits >> mb).astype(jnp.int32)
    mant = bits & ((1 << mb) - 1)
    denorm = expf == 0
    m = jnp.where(denorm, mant, mant | (1 << mb)).astype(x.dtype)
    e = jnp.where(denorm, 1, expf) - (eb + mb)
    return m, e


def compensated_norm(x: jax.Array, axis: Optional[int] = None) -> jax.Array:
    """Overflow/underflow-safe compensated 2-norm ||x||_2.

    ``axis=None`` (default) reduces over all elements; an integer ``axis``
    reduces that axis only (batched).  The operand is pre-scaled by an exact
    power of two at its magnitude ceiling so squared terms neither overflow
    for ~1e200 inputs nor vanish for denormal-only inputs, and the
    compensated accumulation preserves ~2x-working-precision in the sum.

    XLA CPU arithmetic is flush-to-zero/denormals-are-zero, so the scaling
    never touches a denormal with arithmetic: inputs are decomposed into
    ``m * 2**e`` via bit fields (exact, FTZ-immune), scaled by bit-built
    powers of two, and a result that lands in the denormal range is stored
    by integer-rounding its significand and bitcasting — correctly rounded
    where plain arithmetic would flush it to 0.

    Edge cases (explicit, matching ``np.linalg.norm``):
      * all-zero input → 0.0;
      * any NaN → NaN;
      * otherwise any ±inf → +inf.
    """
    x = jnp.asarray(x)
    if axis is None:
        x = x.reshape(-1)
        ax = 0
    else:
        ax = _normalize_axis(axis, x.ndim)
    rec = obs.op_start("reduce", (x.shape[ax],), "xla", None, x, label="nrm2")
    it, mb, eb, _ = _ieee_layout(x.dtype)
    finite = jnp.isfinite(x)
    has_nan = jnp.any(jnp.isnan(x), axis=ax)
    has_inf = jnp.any(jnp.isinf(x), axis=ax)
    # Non-finite entries are masked out of the scaled accumulation so the
    # normal path never produces inf - inf = NaN; the flags override below.
    xf = jnp.where(finite, x, 0.0)
    m, e = _decompose(xf)
    # floor(log2 |x_i|) = e + (exponent of m's leading bit); m is normal or
    # zero here, where frexp is reliable.
    _, mex = jnp.frexp(m)
    sentinel = jnp.int32(-(1 << 30))
    elog = jnp.where(m > 0, e + mex - 1, sentinel)
    es = jnp.max(elog, axis=ax, keepdims=True)
    es = jnp.where(es == sentinel, 0, es)         # all-zero slice: scale 1
    # xs = |x_i| / 2**es, exact: the largest element lands in [1, 2), so
    # squares can neither overflow nor flush.  (Elements so far below absmax
    # that the clip in _pow2 engages contribute < u**4 relatively — below
    # even the compensated bound.)
    xs = m * _pow2(e - es, x.dtype)
    r = jnp.sqrt(_dot_impl(xs, xs, ax, None))          # in [1, ~2*sqrt(n)]
    es = jnp.squeeze(es, ax)
    # Reconstruct r * 2**es.  Two exact power-of-two multiplies cover the
    # normal range (split so neither factor over/underflows); ...
    half = es // 2
    big = (r * _pow2(half, x.dtype)) * _pow2(es - half, x.dtype)
    # ... and a result in the denormal range (or the first normal binade) is
    # t = value * 2**(eb+mb-1) < 2**(mb+1), whose integer rounding IS the
    # result's bit pattern — FTZ'd arithmetic cannot produce these values.
    t = r * _pow2(es + (eb + mb - 1), x.dtype)
    tiny = t < 2.0 ** (mb + 1)
    k = jnp.round(jnp.where(tiny, t, 0.0)).astype(it)
    nrm = jnp.where(tiny, jax.lax.bitcast_convert_type(k, x.dtype), big)
    nrm = jnp.where(has_inf, jnp.asarray(jnp.inf, nrm.dtype), nrm)
    return obs.op_end(rec, jnp.where(has_nan, jnp.asarray(jnp.nan, nrm.dtype),
                                     nrm))


# ---------------------------------------------------------------------------
# Element-wise scan references (the parity oracle for the blocked fast path)
# ---------------------------------------------------------------------------

def neumaier_sum_scan(x: jax.Array, axis: int = -1) -> jax.Array:
    """Kahan-Babuska-Neumaier compensated reduction along ``axis``.

    Element-wise ``lax.scan`` reference (O(n) sequential steps, ~50 ms per
    4096-element call on CPU): retained as the parity/accuracy oracle for the
    blocked fast path, not a production code path.
    """
    xm = jnp.moveaxis(x, axis, 0)

    def step(carry, xi):
        s, c = carry
        t = s + xi
        # Feed the two_sum error of (s + xi) into the compensation stream;
        # branchless form of Neumaier's |s| >= |xi| case split.
        c = c + jnp.where(jnp.abs(s) >= jnp.abs(xi),
                          (s - t) + xi, (xi - t) + s)
        return (t, c), None

    zero = jnp.zeros_like(xm[0])
    (s, c), _ = jax.lax.scan(step, (zero, zero), xm)
    return s + c


def compensated_dot_scan(x: jax.Array, y: jax.Array) -> jax.Array:
    """Element-wise Dot2 scan over 1-D operands — the retained reference
    implementation the blocked ``compensated_dot`` is parity-tested against."""
    p, e = two_prod(x, y)

    def step(carry, inp):
        s, c = carry
        pi, ei = inp
        s, e2 = two_sum(s, pi)
        return (s, c + (e2 + ei)), None

    zero = jnp.zeros((), x.dtype)
    (s, c), _ = jax.lax.scan(step, (zero, zero), (p, e))
    return s + c
