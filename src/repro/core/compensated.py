"""Compensated reductions — the paper's BLAS-1 closure (§7.1(a) + companion FFT).

The dwarf audit routes BLAS-1 (ddot, dnrm2, CG residuals, FFT scalings) onto
the healthy low-precision vector pipe with error-free-transformation
compensation instead of Ozaki emulation.  This module is the canonical home of
those reductions; the error-free transformations themselves (``two_sum``,
``two_prod``, ``fast_two_sum``) live in ``repro.core.numerics`` and are
re-exported here.

Provided reductions (all jit/scan-based, O(n), working-dtype in/out):
  * ``neumaier_sum``     — Kahan-Babuska-Neumaier summation: unlike plain Kahan
    it stays accurate when the running sum is smaller than the next term
    (|error| <= 2u·Σ|x| + O(u²), versus unbounded Kahan failure cases);
  * ``compensated_dot``  — Ogita-Rump Dot2: two_prod each term, two_sum the
    accumulation, carry both error streams — ~twice-working-precision;
  * ``compensated_norm`` — overflow/underflow-safe 2-norm: exact power-of-two
    pre-scaling by the magnitude ceiling, then a compensated sum of exact
    squared-term pairs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.numerics import fast_two_sum, two_prod, two_sum  # noqa: F401

__all__ = ["two_sum", "two_prod", "fast_two_sum", "neumaier_sum",
           "compensated_dot", "compensated_norm"]


def neumaier_sum(x: jax.Array, axis: int = -1) -> jax.Array:
    """Kahan-Babuska-Neumaier compensated reduction along ``axis``."""
    xm = jnp.moveaxis(x, axis, 0)

    def step(carry, xi):
        s, c = carry
        t = s + xi
        # Feed the two_sum error of (s + xi) into the compensation stream;
        # branchless form of Neumaier's |s| >= |xi| case split.
        c = c + jnp.where(jnp.abs(s) >= jnp.abs(xi),
                          (s - t) + xi, (xi - t) + s)
        return (t, c), None

    zero = jnp.zeros_like(xm[0])
    (s, c), _ = jax.lax.scan(step, (zero, zero), xm)
    return s + c


def compensated_dot(x: jax.Array, y: jax.Array) -> jax.Array:
    """Ogita-Rump Dot2 inner product: ~twice-working-precision accuracy.

    Every elementwise product is split exactly with ``two_prod`` and the
    accumulation carries the ``two_sum`` rounding errors, so the result error
    is O(u²·cond) — in FP32 this is the §7.1(a) "FP32 pipe + compensation"
    BLAS-1 path at ~2^-48 effective accuracy.
    """
    p, e = two_prod(x, y)

    def step(carry, inp):
        s, c = carry
        pi, ei = inp
        s, e2 = two_sum(s, pi)
        return (s, c + (e2 + ei)), None

    zero = jnp.zeros((), x.dtype)
    (s, c), _ = jax.lax.scan(step, (zero, zero), (p, e))
    return s + c


def compensated_norm(x: jax.Array) -> jax.Array:
    """Overflow-safe compensated 2-norm ||x||_2.

    The operand is pre-scaled by an exact power of two near its magnitude
    ceiling (division by 2^e is error-free), so squared terms can neither
    overflow at ~1e200 inputs nor flush denormal inputs to zero, and the
    compensated accumulation preserves ~2x-working-precision in the sum.
    """
    x = x.reshape(-1)
    absmax = jnp.max(jnp.abs(x))
    finite = (absmax > 0) & jnp.isfinite(absmax)
    scale = jnp.where(finite, 2.0 ** jnp.floor(jnp.log2(
        jnp.where(finite, absmax, 1.0))), 1.0).astype(x.dtype)
    xs = x / scale
    return scale * jnp.sqrt(compensated_dot(xs, xs))
