"""The Tensor–Memory Equilibrium (TME) model — the paper's analytic contribution (§4).

Classical Roofline (Williams et al.) extended with three emulation parameters:
    α — low-precision MMAs per FP64-equivalent op (≈ r for Ozaki II; 3r on FP8; S² for
        Ozaki I),
    β — bandwidth multiplier (1 for fully fused on-chip decomposition; r unfused),
    γ — per-output reconstruction latency (Garner, O(r²) small int ops).

    T_nat = max(W / P_fp64, Q / B_mem)                            (paper eq. 8)
    T_emu = max(αW / P_low, βQ / B_mem) + γ·n_out                 (paper eq. 9)

This module reproduces the paper's Tables 2–5 and is also the engine behind the
roofline analysis of the dry-runs (launch/roofline.py adds the collective term).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Table 2 — architectural parameters (TFLOPS / TOPS dense, TB/s)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    fp64_vector: float          # TFLOPS
    fp64_tensor: Optional[float]  # TFLOPS (None if absent / emulated-only)
    fp8: float                  # TFLOPS dense
    int8: float                 # TOPS dense
    bf16: float                 # TFLOPS dense
    hbm_tbps: float             # TB/s
    hbm_gb: float
    ici_gbps: float = 0.0       # per-link interconnect GB/s (TPU) / NVLink share

    @property
    def native_ridge(self) -> float:
        """Memory ridge point (FLOPs/Byte) of the native FP64 vector pipe.

        Units: TFLOPS / (TB/s) — the 1e12 factors cancel, leaving FLOPs/Byte
        directly (e.g. H100: 34 / 3.35 ≈ 10.1 F/B, the paper's Table 2 row).
        """
        return self.fp64_vector / self.hbm_tbps

    def fp64_matrix_native(self) -> float:
        return self.fp64_tensor if self.fp64_tensor is not None else self.fp64_vector


H100 = ChipSpec("H100", fp64_vector=34, fp64_tensor=67, fp8=1979, int8=1979,
                bf16=989, hbm_tbps=3.35, hbm_gb=80)
B200 = ChipSpec("B200", fp64_vector=40, fp64_tensor=40, fp8=4500, int8=155,
                bf16=2250, hbm_tbps=8.0, hbm_gb=192)
B300 = ChipSpec("B300", fp64_vector=1.3, fp64_tensor=1.2, fp8=5000, int8=165,
                bf16=2500, hbm_tbps=8.0, hbm_gb=288)
R200 = ChipSpec("R200", fp64_vector=33, fp64_tensor=None, fp8=4000, int8=250,
                bf16=2000, hbm_tbps=22.0, hbm_gb=288)
# The hardware this repo actually targets: TPU v5e (DESIGN.md §3).  No FP64 unit at
# all — fp64_vector is the measured XLA software-emulation rate (~0.4 TFLOPS class),
# making v5e an even starker post-FP64 design point than B300.
TPU_V5E = ChipSpec("TPUv5e", fp64_vector=0.4, fp64_tensor=None, fp8=394, int8=394,
                   bf16=197, hbm_tbps=0.819, hbm_gb=16, ici_gbps=50.0)

CHIPS: Dict[str, ChipSpec] = {c.name: c for c in (H100, B200, B300, R200, TPU_V5E)}


# ---------------------------------------------------------------------------
# Emulation parameters (Def. 1) and the two time equations
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EmulationParams:
    alpha: float               # low-precision MMAs per FP64 op
    beta: float = 1.0          # bandwidth multiplier (1 = fused)
    gamma: float = 0.0         # s per output element (Garner)
    substrate: str = "fp8"     # which P_low to use: "fp8" | "int8" | "bf16"

    @staticmethod
    def ozaki2(r: int = 10, substrate: str = "fp8", fused: bool = True,
               fp8_planes: bool = False) -> "EmulationParams":
        """Paper defaults: α = r; §2.4's (3r+1) plane count if fp8_planes."""
        alpha = (3 * r + 1) if fp8_planes else r
        return EmulationParams(alpha=alpha, beta=1.0 if fused else float(r),
                               substrate=substrate)


def p_low(spec: ChipSpec, substrate: str) -> float:
    return {"fp8": spec.fp8, "int8": spec.int8, "bf16": spec.bf16}[substrate]


def native_time(W: float, Q: float, spec: ChipSpec, matrix: bool = False) -> float:
    """Paper eq. (8).  W in FLOPs, Q in bytes; returns seconds."""
    p = (spec.fp64_matrix_native() if matrix else spec.fp64_vector) * 1e12
    return max(W / p, Q / (spec.hbm_tbps * 1e12))


def emulated_time(W: float, Q: float, n_out: float, spec: ChipSpec,
                  params: EmulationParams) -> float:
    """Paper eq. (9)."""
    p = p_low(spec, params.substrate) * 1e12
    return max(params.alpha * W / p, params.beta * Q / (spec.hbm_tbps * 1e12)) \
        + params.gamma * n_out


def native_perf(oi: float, spec: ChipSpec, matrix: bool = False) -> float:
    """Attainable native FP64 TFLOPS at operational intensity ``oi``."""
    p = spec.fp64_matrix_native() if matrix else spec.fp64_vector
    return min(oi * spec.hbm_tbps, p)


def emulated_perf(oi: float, spec: ChipSpec, params: EmulationParams) -> float:
    """Attainable emulated-FP64 TFLOPS at ``oi`` (γ amortised; paper Fig. 1 curve)."""
    ceiling = p_low(spec, params.substrate) / params.alpha
    return min(oi * spec.hbm_tbps / params.beta, ceiling)


def speedup(oi: float, spec: ChipSpec, params: EmulationParams,
            matrix: bool = False) -> float:
    return emulated_perf(oi, spec, params) / native_perf(oi, spec, matrix)


def crossover_oi(spec: ChipSpec, params: EmulationParams) -> float:
    """OI above which emulation beats native FP64 (paper §4.3 Case A boundary)."""
    # native compute roof == memory roof at native ridge; emulation wins when
    # OI * B > P_fp64 (with β=1):
    return params.beta * spec.fp64_vector / spec.hbm_tbps


def emulation_ridge(spec: ChipSpec, params: EmulationParams) -> float:
    """OI at which the emulated curve leaves the memory roof (its own ridge)."""
    return p_low(spec, params.substrate) / params.alpha / spec.hbm_tbps


# ---------------------------------------------------------------------------
# Per-op cost model for the dispatch seam (the telemetry prediction side)
# ---------------------------------------------------------------------------

# Chip whose TME prediction the telemetry layer compares measurements against.
# Default is the repo's actual compile target (TPU v5e); REPRO_TME_CHIP picks
# any Table-2 entry (e.g. H100) for what-if comparisons.
CHIP_VAR = "REPRO_TME_CHIP"


def default_chip() -> ChipSpec:
    """ChipSpec named by $REPRO_TME_CHIP (default TPUv5e, the compile target)."""
    import os

    name = os.environ.get(CHIP_VAR, "TPUv5e")
    try:
        return CHIPS[name]
    except KeyError:
        raise ValueError(f"{CHIP_VAR} must be one of {sorted(CHIPS)}, "
                         f"got {name!r}") from None


def op_costs(kind: str, dims: Tuple[int, ...]) -> Tuple[float, float, float]:
    """(W FLOPs, Q HBM bytes, n_out) of one FP64-equivalent dispatched op.

    ``dims`` per kind: gemm/gemv (m, k, n); spmv_bell (M, bw, N); stencil7
    (X, Y, Z); reduce (n,).  Q assumes 8-byte working floats (the op being
    *emulated* is FP64 even when the operands arrive in f32 — this is the
    model's native side, paper eq. (8)'s Q).  For reduce, Q charges the
    two-stream Dot2 case (the CG driver); one-stream sums overstate Q by 2x,
    within the model's tolerance.
    """
    if kind in ("gemm", "gemv"):
        m, k, n = (float(d) for d in dims)
        return 2.0 * m * k * n, 8.0 * (m * k + k * n + m * n), m * n
    if kind == "spmv_bell":
        M, bw = float(dims[0]), float(dims[1])
        N = float(dims[2]) if len(dims) > 2 else M
        # values + int32 colidx + x gather (~1x cached) + y
        return 2.0 * M * bw, 8.0 * M * bw + 4.0 * M * bw + 8.0 * N + 8.0 * M, M
    if kind == "stencil7":
        npts = float(dims[0]) * float(dims[1]) * float(dims[2])
        return 14.0 * npts, 16.0 * npts, npts
    if kind == "reduce":
        n = float(dims[0])
        return 2.0 * n, 16.0 * n, 1.0
    if kind == "attention":
        # (B, S, D, T) — B independent rows of S queries against T keys at
        # head dim D; a bare 3-tuple (S, D, T) means B = 1.  W counts the
        # QK^T + PV products (2·2·S·T·D each row); Q is the fused-path f64
        # traffic: q + out (S·D each) and k + v (T·D each); n_out counts the
        # Garner reconstructions (S·T scores + S·D outputs per row).
        if len(dims) == 3:
            dims = (1,) + tuple(dims)
        B, S, D, T = (float(d) for d in dims)
        return (4.0 * B * S * T * D,
                8.0 * B * (2.0 * S * D + 2.0 * T * D),
                B * S * (T + D))
    raise ValueError(f"op_costs: unknown kind {kind!r}")


# Compensated BLAS-1: ~5 vector-pipe flops per plain flop (two_prod + the
# two_sum tree), β = 1 (one streaming pass), no Garner term — §7.1(a)'s
# "healthy vector pipe" path, charged against the bf16 rate as its proxy.
REDUCE_EFT_ALPHA = 5.0


def predict_op_time(kind: str, dims: Tuple[int, ...], r: int = 10,
                    alpha: Optional[float] = None, substrate: str = "int8",
                    route: str = "xla",
                    spec: Optional[ChipSpec] = None) -> float:
    """TME-predicted seconds for one dispatched op (paper eq. (9) pointed at
    our own kernels — the falsifiability instrument the telemetry layer
    compares wall-clock against).

    ``route`` sets β: the fused pallas kernels keep residues on-chip (β = 1);
    the unfused xla references materialise r residue planes (β = r).  γ is the
    ``garner_gamma`` model at this r.  The reduce kind has no emulation at
    all: α is the EFT flop multiplier, β = 1, γ = 0.
    """
    if spec is None:
        spec = default_chip()
    if kind == "attention":
        return attention_emulated_time(dims, r=r, alpha=alpha,
                                       substrate=substrate, route=route,
                                       spec=spec)
    W, Q, n_out = op_costs(kind, dims)
    if kind == "reduce":
        params = EmulationParams(alpha=REDUCE_EFT_ALPHA, beta=1.0,
                                 gamma=0.0, substrate="bf16")
        return emulated_time(W, Q, 0.0, spec, params)
    if alpha is None:
        alpha = float(r) if substrate == "int8" else 3.0 * r
    beta = 1.0 if route == "pallas" else float(r)
    params = EmulationParams(alpha=float(alpha), beta=beta,
                             gamma=garner_gamma(spec, r), substrate=substrate)
    return emulated_time(W, Q, n_out, spec, params)


def attention_emulated_time(dims: Tuple[int, ...], r: int = 10,
                            alpha: Optional[float] = None,
                            substrate: str = "int8", route: str = "xla",
                            spec: Optional[ChipSpec] = None) -> float:
    """TME-predicted seconds for the fused attention kind, per route.

    The pallas route is the FlashAttention-style scan: scores and
    probabilities never leave registers/VMEM, so it is priced like the other
    fused kernels (β = 1 over the q/k/v/out traffic, γ per reconstruction).
    The xla reference composes seam GEMMs per kv block and *materialises*
    the S and P matrices (2·8·B·S·T bytes); that extra traffic is charged
    on top of the residue-plane β = r multiplier (added as q_scores/r so the
    β factor restores it to one full f64 pass each way).
    """
    if spec is None:
        spec = default_chip()
    if len(dims) == 3:
        dims = (1,) + tuple(dims)
    B, S, D, T = (float(d) for d in dims)
    W, Q, n_out = op_costs("attention", dims)
    if alpha is None:
        alpha = float(r) if substrate == "int8" else 3.0 * r
    gamma = garner_gamma(spec, r)
    if route == "pallas":
        params = EmulationParams(alpha=float(alpha), beta=1.0, gamma=gamma,
                                 substrate=substrate)
        return emulated_time(W, Q, n_out, spec, params)
    q_scores = 2.0 * 8.0 * B * S * T
    params = EmulationParams(alpha=float(alpha), beta=float(r), gamma=gamma,
                             substrate=substrate)
    return emulated_time(W, Q + q_scores / float(r), n_out, spec, params)


# ---------------------------------------------------------------------------
# Workloads (Table 3 rows) and table generators
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    oi: float                  # FLOPs / byte of HBM traffic
    matrix: bool               # True → native path uses the FP64 *tensor* rate


WORKLOADS: Tuple[Workload, ...] = (
    Workload("dense_gemm", 100.0, True),
    Workload("bgemv_b8", 4.0, False),
    Workload("bgemv_b2", 1.5, False),
    Workload("stencil_7pt", 0.5, False),
    Workload("spmv", 0.2, False),
)


def table3_speedups(r: int = 10) -> List[dict]:
    """Projected Ozaki II/FP8-over-native speedups (paper Table 3)."""
    rows = []
    params = EmulationParams.ozaki2(r=r, substrate="fp8")
    for w in WORKLOADS:
        row = {"workload": w.name, "oi": w.oi}
        for chip in ("H100", "B200", "B300", "R200"):
            row[chip] = speedup(w.oi, CHIPS[chip], params, matrix=w.matrix)
        rows.append(row)
    return rows


def table4_h100_baseline(r: int = 10) -> List[dict]:
    """Absolute FP64-equivalent TFLOPS and H100-relative scaling (paper Table 4)."""
    rows = []
    params = EmulationParams.ozaki2(r=r, substrate="fp8")
    h100_native = {w.name: native_perf(w.oi, H100, w.matrix) for w in WORKLOADS}
    for w in WORKLOADS:
        for path in ("native", "ozaki2"):
            row = {"workload": w.name, "path": path}
            for chip in ("H100", "B200", "B300", "R200"):
                spec = CHIPS[chip]
                perf = (native_perf(w.oi, spec, w.matrix) if path == "native"
                        else emulated_perf(w.oi, spec, params))
                row[chip] = perf
                row[f"{chip}_vs_h100"] = perf / h100_native[w.name]
            rows.append(row)
    return rows


def table5_substrates(r: int = 10) -> List[dict]:
    """INT8 vs FP8 emulation ceilings (paper Table 5)."""
    rows = []
    for chip in ("H100", "B200", "B300", "R200"):
        spec = CHIPS[chip]
        int8_ceil = spec.int8 / r
        fp8_ceil = spec.fp8 / r
        rows.append({
            "chip": chip, "p_int8": spec.int8, "p_fp8": spec.fp8,
            "ozaki_int8_ceiling": int8_ceil, "ozaki_fp8_ceiling": fp8_ceil,
            "fp8_advantage": fp8_ceil / int8_ceil,
        })
    return rows


def moduli_sensitivity(chip: str = "B300") -> List[dict]:
    """§2.4 sensitivity: the ceiling P_fp8/r at r = 10, 11, 12 (and with 3r+1)."""
    spec = CHIPS[chip]
    rows = []
    for r in (10, 11, 12):
        rows.append({
            "r": r,
            "ceiling_r": spec.fp8 / r,
            "ceiling_3r1": spec.fp8 / (3 * r + 1),
        })
    return rows


# ---------------------------------------------------------------------------
# Bailey four-step FFT stages (companion FFT analysis; Part 2 gamma-roof)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FFTStage:
    """One stage of the four-step FFT in TME terms.

    W is real FLOPs (a complex MAC through the realified GEMM costs 8), Q is
    HBM bytes, n_out the per-stage Garner reconstruction count (the gamma
    multiplier: each GEMM pass reconstructs 2n real outputs per batch element).
    """
    name: str
    W: float
    Q: float
    n_out: float

    def emulated_s(self, spec: ChipSpec, params: EmulationParams) -> float:
        return emulated_time(self.W, self.Q, self.n_out, spec, params)


def bailey_fft_stages(n: int, batch: int = 1,
                      working_bytes: int = 16) -> List[FFTStage]:
    """Per-stage (W, Q, n_out) of the four-step FFT over a length-n batch.

    Mirrors the *recursion* of ``repro.spectral.bailey.dft_stacked`` using the
    same ``choose_factors``/``DENSE_MAX`` the executed transform uses, so the
    model cannot desynchronise from it: each recursion level contributes a
    twiddle scaling and a transpose (pure data movement), and every leaf is a
    dense DFT GEMM ``gemm_n{f}`` — the emulated part, charging 8f MACs-worth
    of real FLOPs per element and a gamma term on its 2n real outputs per
    batch element.  ``working_bytes`` is per complex element (16 for
    FP64-equivalent working precision).
    """
    # Deferred: spectral sits above core in the layering; this is the one
    # place the model reaches up, to stay pinned to the executed factors.
    from repro.spectral.bailey import choose_factors
    from repro.spectral.dft import DENSE_MAX

    pass_q = 2.0 * working_bytes * n * batch          # stream in + out
    factors = choose_factors(n) if n > DENSE_MAX else None
    if factors is None:                               # dense leaf (or prime)
        return [FFTStage(f"gemm_n{n}", 8.0 * n * n * batch, pass_q,
                         2.0 * n * batch)]
    n1, n2 = factors
    stages = list(bailey_fft_stages(n1, n2 * batch, working_bytes))
    stages.append(FFTStage(f"twiddle_n{n}", 6.0 * n * batch,
                           pass_q + working_bytes * n, 0.0))
    stages.append(FFTStage(f"transpose_n{n}", 0.0, pass_q, 0.0))
    stages.extend(bailey_fft_stages(n2, n1 * batch, working_bytes))
    return stages


def garner_gamma(spec: ChipSpec, r: int = 10) -> float:
    """Crude per-output Garner latency model: the O(r²) mixed-radix small-int
    ops charged against the chip's int8 pipe (paper Def. 1's gamma).  Callers
    that measured a real reconstruction rate should pass their own gamma; this
    default exists so the gamma term is non-zero under the paper's defaults."""
    return float(r * r) / (p_low(spec, "int8") * 1e12)


def fft_emulated_time(n: int, spec: ChipSpec, params: EmulationParams,
                      batch: int = 1) -> float:
    """Sum of paper eq. (9) over the four-step stages (gamma terms included)."""
    return sum(s.emulated_s(spec, params) for s in bailey_fft_stages(n, batch))


def fft_native_time(n: int, spec: ChipSpec, batch: int = 1,
                    working_bytes: int = 16) -> float:
    """Native-FP64 radix-2 FFT through paper eq. (8): W = 5 n log2 n."""
    W = 5.0 * n * math.log2(n) * batch
    Q = 2.0 * working_bytes * n * batch
    return native_time(W, Q, spec)


def table_fft(r: int = 10, batch: int = 4096,
              sizes: Tuple[int, ...] = (1 << 10, 1 << 14, 1 << 18)) -> List[dict]:
    """Projected emulated-over-native FFT speedups with the per-stage gamma
    split (the companion paper's gamma-roof view of the spectral dwarf).

    gamma defaults to the ``garner_gamma`` model per chip (so the
    reconstruction term is visible, not silently zero)."""
    rows = []
    base = EmulationParams.ozaki2(r=r, substrate="fp8")
    for n in sizes:
        for chip in ("H100", "B200", "B300", "R200"):
            spec = CHIPS[chip]
            params = dataclasses.replace(base, gamma=garner_gamma(spec, r))
            stages = bailey_fft_stages(n, batch)
            emu = sum(s.emulated_s(spec, params) for s in stages)
            gamma_s = sum(params.gamma * s.n_out for s in stages)
            rows.append({
                "n": n, "chip": chip,
                "native_s": fft_native_time(n, spec, batch),
                "emulated_s": emu,
                "speedup": fft_native_time(n, spec, batch) / emu if emu else 0.0,
                "gamma_fraction": gamma_s / emu if emu else 0.0,
            })
    return rows


# ---------------------------------------------------------------------------
# Three-term roofline for the dry-run analysis (assignment §ROOFLINE)
# ---------------------------------------------------------------------------

# TPU v5e per-chip constants used throughout EXPERIMENTS.md.
PEAK_BF16_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9  # per link


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def fraction_of_roofline(self) -> float:
        """Useful-compute fraction if the kernel ran exactly at its bound."""
        return self.compute_s / self.bound_s if self.bound_s > 0 else 0.0


def roofline_terms(hlo_flops: float, hlo_bytes: float, collective_bytes: float,
                   chips: int, peak_flops: float = PEAK_BF16_FLOPS,
                   hbm_bw: float = HBM_BW, link_bw: float = ICI_BW) -> RooflineTerms:
    """The three terms of the assignment, in seconds (totals across the mesh)."""
    return RooflineTerms(
        compute_s=hlo_flops / (chips * peak_flops),
        memory_s=hlo_bytes / (chips * hbm_bw),
        collective_s=collective_bytes / (chips * link_bw),
    )
