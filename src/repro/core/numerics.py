"""Compensated-summation primitives: the paper's BLAS-1 escape route (§7.1(a)).

The audit in §7.1 routes BLAS-1 reductions (ddot, dnrm2, CG residuals) onto the
healthy FP32 vector pipe with Kahan compensation instead of Ozaki emulation.  These
helpers implement error-free transformations (two_sum / two_prod via FMA-style
splitting), Kahan summation, compensated dot products, and double-single (f32,f32)
carriers used by the Pallas kernels to return FP64-accurate values on hardware with
no FP64 VMEM type.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Pair = Tuple[jax.Array, jax.Array]


def two_sum(a: jax.Array, b: jax.Array) -> Pair:
    """Error-free transformation: a + b = s + e exactly (Knuth)."""
    s = a + b
    v = s - a
    e = (a - (s - v)) + (b - v)
    return s, e


def fast_two_sum(a: jax.Array, b: jax.Array) -> Pair:
    """EFT valid when |a| >= |b| (Dekker)."""
    s = a + b
    e = b - (s - a)
    return s, e


def _veltkamp_split(a: jax.Array, bits: int) -> Pair:
    c = (2.0 ** bits + 1.0) * a
    hi = c - (c - a)
    return hi, a - hi


def two_prod(a: jax.Array, b: jax.Array) -> Pair:
    """Error-free product a*b = p + e (Veltkamp/Dekker splitting; paper §2.1)."""
    p = a * b
    bits = 27 if a.dtype == jnp.float64 else 12
    ah, al = _veltkamp_split(a, bits)
    bh, bl = _veltkamp_split(b, bits)
    e = ((ah * bh - p) + ah * bl + al * bh) + al * bl
    return p, e


def kahan_sum(x: jax.Array, axis: int = -1) -> jax.Array:
    """Kahan-compensated reduction along ``axis`` (scan-based, O(n))."""
    xm = jnp.moveaxis(x, axis, 0)

    def step(carry, xi):
        s, c = carry
        y = xi - c
        t = s + y
        c = (t - s) - y
        return (t, c), None

    (s, _), _ = jax.lax.scan(step, (jnp.zeros_like(xm[0]), jnp.zeros_like(xm[0])), xm)
    return s


def compensated_dot(x: jax.Array, y: jax.Array) -> jax.Array:
    """Dot2-style compensated inner product: ~twice-working-precision accuracy.

    This is the FP32+Kahan BLAS-1 path of §7.1(a): on hardware whose FP64 pipe has
    collapsed, running this in FP32 gives ~2^-48 effective accuracy at FP32 speed.
    The implementation lives in ``repro.core.compensated`` (the canonical home of
    the compensated reductions); this alias is kept for existing callers.
    """
    from repro.core import compensated  # deferred: compensated imports our EFTs
    return compensated.compensated_dot(x, y)


# ---------------------------------------------------------------------------
# Double-single (two-float32) carrier — the kernels' FP64-free output format.
# ---------------------------------------------------------------------------

def ds_from_f64(x: jax.Array) -> Pair:
    """Split float64 into (hi, lo) float32 with hi + lo == x to f32-pair precision."""
    hi = x.astype(jnp.float32)
    lo = (x - hi.astype(jnp.float64)).astype(jnp.float32)
    return hi, lo


def ds_to_f64(hi: jax.Array, lo: jax.Array) -> jax.Array:
    return hi.astype(jnp.float64) + lo.astype(jnp.float64)


def ds_add(a: Pair, b: Pair) -> Pair:
    """Double-single addition (f32 pairs), ~45-bit accuracy."""
    s, e = two_sum(a[0], b[0])
    e = e + a[1] + b[1]
    return fast_two_sum(s, e)
