"""Unified emulation dispatch layer — plan cache + XLA/Pallas routing.

The paper's §8 recommendation is that Ozaki-style emulation live *behind* the
precision-policy interface of the standard libraries, with the register-fused
kernels as the default execution path.  This module is that seam: every
emulated multiplication in the repo (``Policy.dot``, the HPC solvers, the
serving engine, the kernel wrappers, the spectral transforms) resolves its
configuration and its execution path here instead of hand-rolling both at each
call-site.

Three concerns, one layer:

  1. **Plan cache** — ``get_plan`` memoises ``ozaki2.make_plan`` on
     ``(k, payload_bits, substrate, r, margin_bits)`` and primes the Garner
     constants at cache-fill time, so the per-call ``make_plan`` +
     ``required_r`` + Garner recomputation disappears from the hot path
     (previously paid on *every* ``Policy.dot`` trace and every VJP re-plan).

  2. **Shape-normalising router** — one entry point per fused-kernel *kind*
     (``matmul`` covering gemm/gemv, ``spmv`` for Blocked-ELL, ``stencil7``
     for the 7-point stencil) normalises operands (MXU padding for GEMM:
     sublane 8, lane 128), routes, and unpads.  The ``pallas`` route is the
     fused kernel (interpret-mode on CPU, compiled Mosaic on TPU); the
     ``xla`` route is the unfused bit-identical reference
     (``ozaki2.emulated_matmul``, ``ozaki_spmv.spmv_bell_ref``,
     ``ozaki_stencil.stencil7_ref``).  Zero-padding is exact: padded
     rows/columns scale with shift 0 and contribute zero residues, so the two
     routes are *bit-identical* on the unpadded region for every kind.

  3. **Mode override** — the route is selected by, in priority order: an
     explicit ``mode=`` argument, the ``mode_scope``/``set_mode``
     programmatic override, and the ``REPRO_DISPATCH`` environment variable
     (``auto | xla | pallas``, default ``auto``).  ``auto`` resolves through
     the per-kind backend table ``AUTO_ROUTE``: every kind prefers the fused
     kernel on TPU backends and the reference path on CPU (where
     interpret-mode Pallas is a correctness tool, not a fast path — for
     ``spmv_bell`` the interpreted gather graph even costs minutes of XLA
     compile).  Whether the pallas route runs interpreted is *not* routing:
     ``pallas_interpret`` decides it here, per backend, and no caller outside
     this module passes ``interpret=`` for route selection.

  4. **Autotuning table** — ``get_tuning(kind, shape)`` resolves block/tile
     parameters per (kind, shape-class), keyed like the plan cache.  The
     shape-class buckets each dimension to the next power of two, so one
     measured entry covers a band of problem sizes.  Kinds are the fused
     kernel kinds plus ``reduce`` (the blocked-EFT compensated reductions in
     ``repro.core.compensated``, which take their block size from here).  The
     committed ``TUNE_TABLE`` seeds measured defaults; the ``REPRO_TUNE``
     environment variable (inline JSON or a path to a JSON file, shaped
     ``{kind: {shape-class-or-*: {param: value, ...}}}``) overrides entries
     without code changes.  ``choose_route`` consults the table too: an entry
     may pin ``"route": "xla" | "pallas"`` for its shape class, which wins
     over the backend default in ``auto`` mode (explicit modes still win).

  5. **Telemetry** — with ``REPRO_TELEMETRY=counters|trace``
     (``repro.obs.telemetry``), every entry point records its kind,
     shape-class, chosen route, plan r/payload_bits, fenced wall time, and
     the TME-predicted time for the same op; ``get_plan``/``get_tuning``
     count their cache hits and misses.  Recording is tracer-safe (a jitted
     caller records nothing) and free when off.
"""

from __future__ import annotations

import contextlib
import functools
import json
import os
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import ozaki2
from repro.obs import telemetry as obs

MODES = ("auto", "xla", "pallas")
ENV_VAR = "REPRO_DISPATCH"
TUNE_VAR = "REPRO_TUNE"

# Fused-kernel kinds the router understands.  "gemm"/"gemv" share the matmul
# entry point (split on RHS width); "spmv_bell", "stencil7", and "attention"
# (the fused online-softmax scan) have their own.
KINDS = ("gemm", "gemv", "spmv_bell", "stencil7", "attention")

# Kinds the autotuning table covers: the fused-kernel kinds plus the
# blocked-EFT compensated reductions (no fused Pallas kernel yet — the blocked
# jnp pipeline *is* the vector-pipe fast path, so its route is always "xla").
TUNE_KINDS = KINDS + ("reduce",)

# Per-kind auto-route defaults by backend family.  One table instead of the
# old per-wrapper ``_default_interpret()`` logic: the fused kernels are the
# production route on TPU for every kind; on CPU/GPU the bit-identical
# reference is the fast path (the Pallas interpreter is a parity oracle —
# and for spmv_bell its gather graph pays a multi-minute XLA-CPU compile).
AUTO_ROUTE = {
    "gemm": {"tpu": "pallas", "default": "xla"},
    "gemv": {"tpu": "pallas", "default": "xla"},
    "spmv_bell": {"tpu": "pallas", "default": "xla"},
    "stencil7": {"tpu": "pallas", "default": "xla"},
    "attention": {"tpu": "pallas", "default": "xla"},
    "reduce": {"default": "xla"},
}

# MXU geometry (Pallas TPU tiling constraints): second-minor axis in sublane
# multiples of 8, minor axis in lane multiples of 128.
SUBLANE = 8
LANE = 128
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 256

# Per-thread override so concurrent engines (e.g. two ServeEngines tracing
# under different modes) cannot interleave each other's route resolution.
_tls = threading.local()


# ---------------------------------------------------------------------------
# Mode resolution
# ---------------------------------------------------------------------------

def _validate_mode(mode: str) -> str:
    if mode not in MODES:
        raise ValueError(f"dispatch mode must be one of {MODES}, got {mode!r}")
    return mode


def get_mode() -> str:
    """Effective dispatch mode: programmatic override, else env, else auto."""
    override = getattr(_tls, "mode", None)
    if override is not None:
        return override
    return _validate_mode(os.environ.get(ENV_VAR, "auto"))


def set_mode(mode: Optional[str]) -> None:
    """Set (or with None, clear) this thread's dispatch-mode override."""
    _tls.mode = None if mode is None else _validate_mode(mode)


@contextlib.contextmanager
def mode_scope(mode: Optional[str]):
    """Temporarily force a dispatch mode (None = inherit the ambient mode)."""
    prev = getattr(_tls, "mode", None)
    set_mode(mode if mode is not None else prev)
    try:
        yield
    finally:
        _tls.mode = prev


# ---------------------------------------------------------------------------
# Plan / Garner-constant cache
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _cached_plan(k: int, payload_bits: int, substrate: str, r: Optional[int],
                 margin_bits: int) -> ozaki2.Plan:
    plan = ozaki2.make_plan(k, payload_bits, r=r, substrate=substrate,
                            margin_bits=margin_bits)
    plan.garner  # noqa: B018 — prime the Garner constants at cache-fill time
    return plan


def get_plan(k: int, payload_bits: int = 53, substrate: str = "int8",
             r: Optional[int] = None, margin_bits: int = 2) -> ozaki2.Plan:
    """Cache-resolved Plan for contractions of length k (Garner pre-primed).

    Semantically identical to ``ozaki2.make_plan`` but amortised: repeated
    lookups (every policy dot, every VJP re-plan, every CG iteration) return
    the same object without re-running moduli selection or Garner setup.
    """
    if obs.enabled():
        before = _cached_plan.cache_info().misses
        plan = _cached_plan(int(k), int(payload_bits), substrate, r, margin_bits)
        obs.record_cache("plan", _cached_plan.cache_info().misses == before)
        return plan
    return _cached_plan(int(k), int(payload_bits), substrate, r, margin_bits)


def plan_cache_info():
    """lru_cache statistics for the plan cache (tests / benchmarks)."""
    return _cached_plan.cache_info()


def clear_plan_cache() -> None:
    """Drop every memoised Plan (tests that vary moduli/payload per case)."""
    _cached_plan.cache_clear()


# ---------------------------------------------------------------------------
# Autotuning table: (kind, shape-class) -> block/tile parameters
# ---------------------------------------------------------------------------

# Seeded (measured) tuning entries.  "*" is the per-kind wildcard; specific
# shape-classes (see ``shape_class``) override it.  GEMM/GEMV entries mirror
# the MXU defaults (DEFAULT_BM/BN/BK); spmv_bell/stencil7 carry the kernel
# defaults so every kind resolves its blocking here rather than in
# per-call-site constants.
TUNE_TABLE: Dict[Tuple[str, str], Dict[str, Any]] = {
    ("gemm", "*"): {"bm": 128, "bn": 128, "bk": 256},
    ("gemv", "*"): {"bm": 128, "bk": 256},
    ("spmv_bell", "*"): {"br": 128},
    ("stencil7", "*"): {"bz": 8},
    ("attention", "*"): {"bq": 128, "bkv": 128},
    ("reduce", "*"): {"block": 512},
    # Measured on CPU (f64 compensated_dot sweep): short vectors are
    # dispatch-bound and flat across blocks; >=64k-element reductions favor
    # the shorter 256-lane block (smaller carry scan wins over tree width).
    ("reduce", "65536"): {"block": 256},
    ("reduce", "131072"): {"block": 256},
}


def _next_pow2(n: int) -> int:
    n = max(1, int(n))
    return 1 << (n - 1).bit_length()


def shape_class(dims: Sequence[int]) -> str:
    """Bucket a shape into its tuning class: each dim rounded up to the next
    power of two, joined with "x" (e.g. (100, 64, 24) -> "128x64x32")."""
    return "x".join(str(_next_pow2(d)) for d in dims)


@functools.lru_cache(maxsize=None)
def _tune_overrides(env: str) -> Dict[Tuple[str, str], Dict[str, Any]]:
    """Parse REPRO_TUNE (inline JSON, or a path to a JSON file) into the same
    (kind, class) -> params mapping as TUNE_TABLE.  Malformed input raises —
    a silently-ignored tuning override is worse than a loud one."""
    if not env:
        return {}
    text = env
    if not env.lstrip().startswith("{"):
        with open(env) as fh:
            text = fh.read()
    raw = json.loads(text)
    table: Dict[Tuple[str, str], Dict[str, Any]] = {}
    for kind, classes in raw.items():
        if kind not in TUNE_KINDS:
            raise ValueError(f"{TUNE_VAR}: unknown kind {kind!r} "
                             f"(expected one of {TUNE_KINDS})")
        for cls, params in classes.items():
            table[(kind, str(cls))] = dict(params)
    return table


@functools.lru_cache(maxsize=None)
def _cached_tuning(kind: str, cls: str, env: str) -> Dict[str, Any]:
    merged: Dict[str, Any] = {}
    overrides = _tune_overrides(env)
    for layer in (TUNE_TABLE.get((kind, "*")), TUNE_TABLE.get((kind, cls)),
                  overrides.get((kind, "*")), overrides.get((kind, cls))):
        if layer:
            merged.update(layer)
    return merged


def get_tuning(kind: str, dims: Sequence[int]) -> Dict[str, Any]:
    """Tuning parameters for ``kind`` at this shape-class (memoised, like the
    plan cache): seeded TUNE_TABLE defaults layered under any REPRO_TUNE
    overrides, most-specific last.  Returns a (shared) dict — treat as
    read-only."""
    if kind not in TUNE_KINDS:
        raise ValueError(f"tuning kind must be one of {TUNE_KINDS}, got {kind!r}")
    args = (kind, shape_class(dims), os.environ.get(TUNE_VAR, ""))
    if obs.enabled():
        before = _cached_tuning.cache_info().misses
        tuning = _cached_tuning(*args)
        obs.record_cache("tune", _cached_tuning.cache_info().misses == before)
        return tuning
    return _cached_tuning(*args)


def clear_tune_cache() -> None:
    """Drop memoised tuning lookups (tests flip REPRO_TUNE between cases)."""
    _cached_tuning.cache_clear()
    _tune_overrides.cache_clear()


def reduce_block(n: int) -> int:
    """Block size for the blocked-EFT reductions over length-n operands —
    the ``repro.core.compensated`` fast path resolves its blocking here."""
    return max(1, int(get_tuning("reduce", (n,)).get("block", 512)))


# ---------------------------------------------------------------------------
# Shape normalisation
# ---------------------------------------------------------------------------

def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def choose_blocks(m: int, k: int, n: int) -> Tuple[int, int, int]:
    """MXU-friendly (bm, bn, bk) for an (m, k) x (k, n) problem.

    The target tiling comes from the autotuning table (kind "gemm"/"gemv" by
    RHS width, default 128/128/256); smaller axes shrink to the dimension
    rounded up to the hardware granule (sublane 8 for the second-minor m-axis,
    lane 128 for the minor n/k axes) so padding stays bounded while tiles keep
    legal Mosaic shapes.  Tuned values are clamped to the same legality rules,
    so a bad REPRO_TUNE entry degrades performance, never correctness.
    """
    tune = get_tuning(_matmul_kind(n), (m, k, n))
    tbm = int(tune.get("bm", DEFAULT_BM))
    tbn = int(tune.get("bn", DEFAULT_BN))
    tbk = int(tune.get("bk", DEFAULT_BK))
    bm = _round_up(tbm, SUBLANE) if m >= tbm else _round_up(m, SUBLANE)
    bn = _round_up(tbn, LANE) if n >= tbn else _round_up(n, LANE)
    # bk must divide the lane-padded K; falling back to one lane (128) keeps
    # the K padding at < one lane of zeros (bk=256 on k=257 would pad to 512).
    tbk = max(LANE, _round_up(tbk, LANE))
    kp = _round_up(k, LANE)
    bk = tbk if kp % tbk == 0 else LANE
    return bm, bn, bk


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pad_operands(a: jax.Array, b: jax.Array,
                 blocks: Optional[Tuple[int, int, int]] = None
                 ) -> Tuple[jax.Array, jax.Array, Tuple[int, int, int]]:
    """Zero-pad (m,k)x(k,n) operands to block multiples.  Exactness: padded
    rows/cols are all-zero, scale with shift 0 and contribute zero residues,
    so the product over the real region is unchanged bit-for-bit."""
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = blocks if blocks is not None else choose_blocks(m, k, n)
    a = _pad_axis(_pad_axis(a, 0, bm), 1, bk)
    b = _pad_axis(_pad_axis(b, 0, bk), 1, bn)
    return a, b, (bm, bn, bk)


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------

def _validate_kind(kind: str) -> str:
    if kind not in TUNE_KINDS:
        raise ValueError(f"dispatch kind must be one of {TUNE_KINDS}, "
                         f"got {kind!r}")
    return kind


def pallas_supported(plan: Optional[ozaki2.Plan], kind: str = "gemm") -> bool:
    """The fused kernels implement the int8 residue substrate only; the FP8
    Karatsuba substrate runs through the XLA reference path (every kind).
    The ``reduce`` kind has no fused kernel at all — its blocked-EFT jnp
    pipeline is the vector-pipe fast path."""
    _validate_kind(kind)
    if kind == "reduce":
        return False
    return plan is not None and plan.substrate == "int8"


def choose_route(plan: Optional[ozaki2.Plan], kind: str = "gemm",
                 mode: Optional[str] = None,
                 shape: Optional[Sequence[int]] = None) -> str:
    """Resolve a concrete route ('xla' | 'pallas') for this plan/kind/mode.

    ``shape`` (the operand dimensions, optional) lets ``auto`` mode consult
    the autotuning table: a tuning entry carrying ``"route"`` pins the route
    for its (kind, shape-class) ahead of the backend default — e.g. forcing
    tiny problems onto the reference path even on TPU.  Explicit modes and
    substrate support still win over the table.
    """
    _validate_kind(kind)
    mode = _validate_mode(mode) if mode is not None else get_mode()
    if mode == "xla" or not pallas_supported(plan, kind):
        return "xla"
    if mode == "pallas":
        return "pallas"
    if shape is not None:
        route = get_tuning(kind, shape).get("route")
        if route is not None:
            if route not in ("xla", "pallas"):
                raise ValueError(f"tuned route must be 'xla' or 'pallas', "
                                 f"got {route!r}")
            return route
    table = AUTO_ROUTE[kind]
    return table.get(jax.default_backend(), table["default"])


def pallas_interpret(kind: str = "gemm") -> bool:
    """Whether the pallas route runs the kernel interpreter on this backend.

    This is the *execution flavour* of the fused route, not route selection:
    on TPU the kernels lower through Mosaic, everywhere else they run under
    the Pallas interpreter.  Callers outside this module never pass
    ``interpret=`` to pick a path — they pass ``mode=`` and land here.
    """
    _validate_kind(kind)
    return jax.default_backend() != "tpu"


def _working_float():
    return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32


# RHS widths at or below this route to the fused batched-GEMV kernel (paper
# Alg. 1's small-B regime) instead of padding the N axis up to a full GEMM lane.
GEMV_MAX_B = 16


def _matmul_kind(n: int) -> str:
    """gemm vs gemv: narrow RHS routes to the fused batched-GEMV kernel."""
    return "gemv" if n <= GEMV_MAX_B else "gemm"


def _pallas_matmul(a: jax.Array, b: jax.Array, plan: ozaki2.Plan) -> jax.Array:
    from repro.kernels import ops  # deferred: kernels import core, not vice versa

    m, k = a.shape
    n = b.shape[1]
    if _matmul_kind(n) == "gemv":
        # Narrow RHS (matvec / small batch): the GEMV kernel keeps B on the MXU
        # minor dim rather than zero-padding it to a 128-wide GEMM tile.
        bm, _, bk = choose_blocks(m, k, n)
        ap = _pad_axis(_pad_axis(a, 0, bm), 1, bk)
        bp = _pad_axis(b, 0, bk)
        out = ops.ozaki_gemv(ap, bp, plan=plan, bm=bm, bk=bk,
                             interpret=pallas_interpret("gemv"))
        return out[:m]
    ap, bp, (bm, bn, bk) = pad_operands(a, b)
    out = ops.ozaki_gemm(ap, bp, plan=plan, bm=bm, bn=bn, bk=bk,
                         interpret=pallas_interpret("gemm"))
    return out[:m, :n]


def matmul(a: jax.Array, b: jax.Array, plan: Optional[ozaki2.Plan] = None,
           payload_bits: int = 53, substrate: str = "int8",
           mode: Optional[str] = None) -> jax.Array:
    """Emulated FP64-accurate C = A @ B through the dispatch layer.

    a: (m, k), b: (k, n); returns working-float (m, n) regardless of route —
    callers needing the kernel-native digits/ds output representations use
    ``repro.kernels.ops`` directly.  The plan comes from the process cache
    unless given explicitly; the execution path follows ``choose_route``.
    """
    if plan is None:
        plan = get_plan(a.shape[-1], payload_bits, substrate)
    kind = _matmul_kind(b.shape[1])
    shape = (a.shape[0], a.shape[1], b.shape[1])
    route = choose_route(plan, kind, mode, shape=shape)
    rec = obs.op_start(kind, shape, route, plan, a, b)
    if route == "pallas":
        out = _pallas_matmul(a, b, plan)
    else:
        out = ozaki2.emulated_matmul(a, b, plan, out_dtype=_working_float())
    return obs.op_end(rec, out)


def dot(x: jax.Array, w: jax.Array, plan: Optional[ozaki2.Plan] = None,
        payload_bits: int = 53, substrate: str = "int8",
        mode: Optional[str] = None) -> jax.Array:
    """(..., k) x (k, n) emulated dot — the shape contract of ``Policy.dot``."""
    lead = x.shape[:-1]
    out = matmul(x.reshape((-1, x.shape[-1])), w, plan=plan,
                 payload_bits=payload_bits, substrate=substrate, mode=mode)
    return out.reshape(lead + (w.shape[-1],))


def spmv(a_val: jax.Array, a_col: jax.Array, x: jax.Array,
         plan: Optional[ozaki2.Plan] = None, out_rep: str = "f64",
         br: Optional[int] = None, mode: Optional[str] = None) -> jax.Array:
    """Emulated Blocked-ELL SpMV y = A x through the dispatch layer.

    a_val: (M, bw) padded per-row nonzero values, a_col: (M, bw) int32 column
    indices, x: (N,).  Same contract as ``matmul``: the plan resolves from the
    process cache (k = bw, stencil/SpMV margin), the route follows
    ``choose_route(plan, "spmv_bell", mode)``, and the two routes are
    bit-identical — the fused kernel pads M up to the row-block internally and
    unpads before returning, with all-zero padded rows contributing nothing.
    """
    # Deferred module import (kernels import core, not vice versa); attribute
    # access at call time keeps the route monkeypatch-able in tests.
    from repro.kernels import ozaki_spmv as _spmv

    if plan is None:
        plan = get_plan(a_val.shape[1], margin_bits=4)
    route = choose_route(plan, "spmv_bell", mode, shape=a_val.shape)
    rec = obs.op_start("spmv_bell",
                       (a_val.shape[0], a_val.shape[1], x.shape[0]),
                       route, plan, a_val, a_col, x)
    if route == "pallas":
        if br is None:
            br = int(get_tuning("spmv_bell", a_val.shape).get("br", 128))
        out = _spmv.spmv_bell(a_val, a_col, x, plan, out_rep=out_rep,
                              br=br, interpret=pallas_interpret("spmv_bell"))
    else:
        out = _spmv.spmv_bell_ref(a_val, a_col, x, plan, out_rep=out_rep)
    return obs.op_end(rec, out)


def stencil7(u: jax.Array, c: jax.Array, plan: Optional[ozaki2.Plan] = None,
             out_rep: str = "f64", bz: Optional[int] = None,
             mode: Optional[str] = None) -> jax.Array:
    """Emulated 7-point stencil v = S[c] u through the dispatch layer.

    u: (X, Y, Z) grid, c: (7,) coefficients ordered
    [centre, -x, +x, -y, +y, -z, +z]; boundary points see a zero halo.  The
    route follows ``choose_route(plan, "stencil7", mode)``: the fused Pallas
    kernel (z-axis blocked, padded and unpadded internally) vs the
    bit-identical jnp reference ``ozaki_stencil.stencil7_ref``.
    """
    from repro.kernels import ozaki_stencil as _stencil

    if plan is None:
        plan = get_plan(8, margin_bits=4)
    route = choose_route(plan, "stencil7", mode, shape=u.shape)
    rec = obs.op_start("stencil7", u.shape, route, plan, u, c)
    if route == "pallas":
        if bz is None:
            bz = int(get_tuning("stencil7", u.shape).get("bz", 8))
        out = _stencil.stencil7(u, c, plan, out_rep=out_rep, bz=bz,
                                interpret=pallas_interpret("stencil7"))
    else:
        out = _stencil.stencil7_ref(u, c, plan, out_rep=out_rep)
    return obs.op_end(rec, out)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              mask: Optional[jax.Array] = None, softcap: float = 0.0,
              plan_qk: Optional[ozaki2.Plan] = None,
              plan_pv: Optional[ozaki2.Plan] = None,
              payload_bits: int = 53, substrate: str = "int8",
              mode: Optional[str] = None) -> jax.Array:
    """Fused emulated attention out = softmax(mask(QKᵀ/√D + softcap)) V.

    q: (..., S, D) queries, k/v: (..., T, D) keys/values; leading dims (batch,
    heads, ...) are flattened and mapped.  ``mask`` is None (attend to all),
    a shared (S, T) array, or batched (..., S, T); nonzero/True = attend.
    ``softcap`` > 0 applies the tanh logit cap between scaling and masking
    (the models' score order).  Returns working-float (..., S, D).

    Routing follows ``choose_route(plan_qk, "attention", mode)``: the pallas
    route is the FlashAttention-style fused kernel whose QKᵀ and PV products
    ride the Ozaki-II residue pipeline inside one online-softmax scan; the
    xla route is the bit-identical ``attention_ref`` composed from the seam
    GEMMs at the same kv-blocking.  ``plan_qk`` covers the length-D score
    contraction, ``plan_pv`` the length-bkv probability-value contraction;
    both resolve from the plan cache when omitted.  Telemetry records the
    op with a ``prefill`` (S > 1) or ``decode`` (S = 1) label so the two
    serving shape classes stay distinguishable in the measured-vs-TME table.
    """
    from repro.kernels import ozaki_attention as _attn

    lead = q.shape[:-2]
    S, D = q.shape[-2:]
    T = k.shape[-2]
    B = 1
    for d in lead:
        B *= int(d)
    tune = get_tuning("attention", (B, S, D, T))
    bq = min(_round_up(int(tune.get("bq", 128)), SUBLANE),
             _round_up(S, SUBLANE))
    bkv = min(_round_up(int(tune.get("bkv", 128)), SUBLANE),
              _round_up(T, SUBLANE))
    if plan_qk is None:
        plan_qk = get_plan(D, payload_bits, substrate)
    if plan_pv is None:
        plan_pv = get_plan(bkv, payload_bits, substrate)
    route = choose_route(plan_qk, "attention", mode, shape=(B, S, D, T))
    rec = obs.op_start("attention", (B, S, D, T), route, plan_qk, q, k, v,
                       label="decode" if S == 1 else "prefill")
    wf = _working_float()
    if mask is None:
        mask = jnp.ones((S, T), jnp.int8)
    if mask.ndim == 2:
        mask = jnp.broadcast_to(mask.astype(jnp.int8), (B, S, T))
    else:
        mask = mask.astype(jnp.int8).reshape(B, S, T)
    qf = q.astype(wf).reshape(B, S, D)
    kf = k.astype(wf).reshape(B, T, D)
    vf = v.astype(wf).reshape(B, T, D)
    if route == "pallas":
        def one(args):
            qi, ki, vi, mi = args
            return _attn.attention_fused(
                qi, ki, vi, mi, plan_qk, plan_pv, softcap=softcap, bq=bq,
                bkv=bkv, interpret=pallas_interpret("attention"),
                out_dtype=wf)
    else:
        def one(args):
            qi, ki, vi, mi = args
            return _attn.attention_ref(qi, ki, vi, mi, plan_qk, plan_pv,
                                       softcap=softcap, bkv=bkv, out_dtype=wf)
    out = jax.lax.map(one, (qf, kf, vf, mask))
    return obs.op_end(rec, out.reshape(lead + (S, D)))
