"""Unified emulation dispatch layer — plan cache + XLA/Pallas routing.

The paper's §8 recommendation is that Ozaki-style emulation live *behind* the
precision-policy interface of the standard libraries, with the register-fused
kernels as the default execution path.  This module is that seam: every
emulated matmul in the repo (``Policy.dot``, the HPC solvers, the serving
engine, the kernel wrappers) resolves its configuration and its execution path
here instead of hand-rolling both at each call-site.

Three concerns, one layer:

  1. **Plan cache** — ``get_plan`` memoises ``ozaki2.make_plan`` on
     ``(k, payload_bits, substrate, r, margin_bits)`` and primes the Garner
     constants at cache-fill time, so the per-call ``make_plan`` +
     ``required_r`` + Garner recomputation disappears from the hot path
     (previously paid on *every* ``Policy.dot`` trace and every VJP re-plan).

  2. **Shape-normalising router** — ``matmul`` pads arbitrary ``(m, k, n)``
     operands up to MXU-friendly block multiples (sublane 8, lane 128) and
     dispatches to the fused Pallas ``gemm_hilo`` kernel (interpret-mode on
     CPU, compiled Mosaic on TPU) when the substrate supports it, falling back
     to the unfused XLA reference ``ozaki2.emulated_matmul`` otherwise.
     Zero-padding is exact: padded rows/columns scale with shift 0 and
     contribute zero residues, so the pallas route is *bit-identical* to the
     XLA route on the unpadded region.

  3. **Mode override** — the route is selected by, in priority order: an
     explicit ``mode=`` argument, the ``mode_scope``/``set_mode``
     programmatic override, and the ``REPRO_DISPATCH`` environment variable
     (``auto | xla | pallas``, default ``auto``).  ``auto`` prefers the fused
     kernel on TPU backends and the XLA path on CPU (where interpret-mode
     Pallas is a correctness tool, not a fast path).
"""

from __future__ import annotations

import contextlib
import functools
import os
import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ozaki2

MODES = ("auto", "xla", "pallas")
ENV_VAR = "REPRO_DISPATCH"

# MXU geometry (Pallas TPU tiling constraints): second-minor axis in sublane
# multiples of 8, minor axis in lane multiples of 128.
SUBLANE = 8
LANE = 128
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 256

# Per-thread override so concurrent engines (e.g. two ServeEngines tracing
# under different modes) cannot interleave each other's route resolution.
_tls = threading.local()


# ---------------------------------------------------------------------------
# Mode resolution
# ---------------------------------------------------------------------------

def _validate_mode(mode: str) -> str:
    if mode not in MODES:
        raise ValueError(f"dispatch mode must be one of {MODES}, got {mode!r}")
    return mode


def get_mode() -> str:
    """Effective dispatch mode: programmatic override, else env, else auto."""
    override = getattr(_tls, "mode", None)
    if override is not None:
        return override
    return _validate_mode(os.environ.get(ENV_VAR, "auto"))


def set_mode(mode: Optional[str]) -> None:
    """Set (or with None, clear) this thread's dispatch-mode override."""
    _tls.mode = None if mode is None else _validate_mode(mode)


@contextlib.contextmanager
def mode_scope(mode: Optional[str]):
    """Temporarily force a dispatch mode (None = inherit the ambient mode)."""
    prev = getattr(_tls, "mode", None)
    set_mode(mode if mode is not None else prev)
    try:
        yield
    finally:
        _tls.mode = prev


# ---------------------------------------------------------------------------
# Plan / Garner-constant cache
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _cached_plan(k: int, payload_bits: int, substrate: str, r: Optional[int],
                 margin_bits: int) -> ozaki2.Plan:
    plan = ozaki2.make_plan(k, payload_bits, r=r, substrate=substrate,
                            margin_bits=margin_bits)
    plan.garner  # noqa: B018 — prime the Garner constants at cache-fill time
    return plan


def get_plan(k: int, payload_bits: int = 53, substrate: str = "int8",
             r: Optional[int] = None, margin_bits: int = 2) -> ozaki2.Plan:
    """Cache-resolved Plan for contractions of length k (Garner pre-primed).

    Semantically identical to ``ozaki2.make_plan`` but amortised: repeated
    lookups (every policy dot, every VJP re-plan, every CG iteration) return
    the same object without re-running moduli selection or Garner setup.
    """
    return _cached_plan(int(k), int(payload_bits), substrate, r, margin_bits)


def plan_cache_info():
    """lru_cache statistics for the plan cache (tests / benchmarks)."""
    return _cached_plan.cache_info()


def clear_plan_cache() -> None:
    _cached_plan.cache_clear()


# ---------------------------------------------------------------------------
# Shape normalisation
# ---------------------------------------------------------------------------

def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def choose_blocks(m: int, k: int, n: int) -> Tuple[int, int, int]:
    """MXU-friendly (bm, bn, bk) for an (m, k) x (k, n) problem.

    Large problems use the default 128/128/256 tiling; smaller axes shrink to
    the dimension rounded up to the hardware granule (sublane 8 for the
    second-minor m-axis, lane 128 for the minor n/k axes) so padding stays
    bounded while tiles keep legal Mosaic shapes.
    """
    bm = DEFAULT_BM if m >= DEFAULT_BM else _round_up(m, SUBLANE)
    bn = DEFAULT_BN if n >= DEFAULT_BN else _round_up(n, LANE)
    # bk must divide the lane-padded K; falling back to one lane (128) keeps
    # the K padding at < one lane of zeros (bk=256 on k=257 would pad to 512).
    kp = _round_up(k, LANE)
    bk = DEFAULT_BK if kp % DEFAULT_BK == 0 else LANE
    return bm, bn, bk


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pad_operands(a: jax.Array, b: jax.Array,
                 blocks: Optional[Tuple[int, int, int]] = None
                 ) -> Tuple[jax.Array, jax.Array, Tuple[int, int, int]]:
    """Zero-pad (m,k)x(k,n) operands to block multiples.  Exactness: padded
    rows/cols are all-zero, scale with shift 0 and contribute zero residues,
    so the product over the real region is unchanged bit-for-bit."""
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = blocks if blocks is not None else choose_blocks(m, k, n)
    a = _pad_axis(_pad_axis(a, 0, bm), 1, bk)
    b = _pad_axis(_pad_axis(b, 0, bk), 1, bn)
    return a, b, (bm, bn, bk)


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------

def pallas_supported(plan: ozaki2.Plan) -> bool:
    """The fused kernels implement the int8 residue substrate only; the FP8
    Karatsuba substrate runs through the XLA reference path."""
    return plan.substrate == "int8"


def choose_route(plan: ozaki2.Plan, mode: Optional[str] = None) -> str:
    """Resolve a concrete route ('xla' | 'pallas') for this plan and mode."""
    mode = _validate_mode(mode) if mode is not None else get_mode()
    if mode == "xla" or not pallas_supported(plan):
        return "xla"
    if mode == "pallas":
        return "pallas"
    # auto: the fused path is the production route on TPU; on CPU the Pallas
    # interpreter is a correctness oracle, not a fast path.
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _working_float():
    return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32


# RHS widths at or below this route to the fused batched-GEMV kernel (paper
# Alg. 1's small-B regime) instead of padding the N axis up to a full GEMM lane.
GEMV_MAX_B = 16


def _pallas_matmul(a: jax.Array, b: jax.Array, plan: ozaki2.Plan) -> jax.Array:
    from repro.kernels import ops  # deferred: kernels import core, not vice versa

    m, k = a.shape
    n = b.shape[1]
    if n <= GEMV_MAX_B:
        # Narrow RHS (matvec / small batch): the GEMV kernel keeps B on the MXU
        # minor dim rather than zero-padding it to a 128-wide GEMM tile.
        bm, _, bk = choose_blocks(m, k, n)
        ap = _pad_axis(_pad_axis(a, 0, bm), 1, bk)
        bp = _pad_axis(b, 0, bk)
        out = ops.ozaki_gemv(ap, bp, plan=plan, bm=bm, bk=bk)
        return out[:m]
    ap, bp, (bm, bn, bk) = pad_operands(a, b)
    out = ops.ozaki_gemm(ap, bp, plan=plan, bm=bm, bn=bn, bk=bk)
    return out[:m, :n]


def matmul(a: jax.Array, b: jax.Array, plan: Optional[ozaki2.Plan] = None,
           payload_bits: int = 53, substrate: str = "int8",
           mode: Optional[str] = None) -> jax.Array:
    """Emulated FP64-accurate C = A @ B through the dispatch layer.

    a: (m, k), b: (k, n); returns working-float (m, n) regardless of route —
    callers needing the kernel-native digits/ds output representations use
    ``repro.kernels.ops`` directly.  The plan comes from the process cache
    unless given explicitly; the execution path follows ``choose_route``.
    """
    if plan is None:
        plan = get_plan(a.shape[-1], payload_bits, substrate)
    if choose_route(plan, mode) == "pallas":
        return _pallas_matmul(a, b, plan)
    return ozaki2.emulated_matmul(a, b, plan, out_dtype=_working_float())


def dot(x: jax.Array, w: jax.Array, plan: Optional[ozaki2.Plan] = None,
        payload_bits: int = 53, substrate: str = "int8",
        mode: Optional[str] = None) -> jax.Array:
    """(..., k) x (k, n) emulated dot — the shape contract of ``Policy.dot``."""
    lead = x.shape[:-1]
    out = matmul(x.reshape((-1, x.shape[-1])), w, plan=plan,
                 payload_bits=payload_bits, substrate=substrate, mode=mode)
    return out.reshape(lead + (w.shape[-1],))
