"""Phase-1 integer scaling and the TPU-native (hi, lo) int32 operand representation.

Paper mapping (Matsuoka 2026 §2.3 Phase 1, Appendix C):
  * ``scale_to_int`` implements Ã = ⌊D A⌉ with power-of-two diagonal D chosen per row
    (or per column for the right operand) so the largest entry uses the full payload
    width p.  Power-of-two scaling is exact in FP64, so D^{-1} Ĉ E^{-1} is error-free.
  * ``split_hi_lo`` is the hardware adaptation documented in DESIGN.md §3: TPUs have no
    FP64 VMEM type and no fast int64, so the 53-bit scaled integer is carried as an
    exact pair of int32 halves, x = hi * 2^26 + lo.  8 bytes/element — identical HBM
    traffic to native FP64, which is what keeps the TME bandwidth multiplier β = 1.
  * ``residues_from_hilo`` computes balanced residues mod m using int32 arithmetic only
    ((hi mod m) * (2^26 mod m) + lo) mod m — bit-exact vs the int64 oracle (tested).
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.moduli import SPLIT_BITS, SPLIT_RADIX


def scale_to_int(x: jax.Array, payload_bits: int, axis: int) -> Tuple[jax.Array, jax.Array]:
    """Round x (float) to integers after exact power-of-two scaling along ``axis``.

    Returns (xi, shift):
      xi    : float64 array holding exact integers with |xi| < 2**payload_bits
      shift : int32 per-row/col exponents with  xi ≈ x * 2**shift  (exact pow2 scale)

    Rows (slices along ``axis``) that are entirely zero get shift 0.
    """
    ax = axis % x.ndim
    absmax = jnp.max(jnp.abs(x), axis=ax, keepdims=True)
    # exponent e with 2**e <= absmax < 2**(e+1); for absmax == 0 use e = 0.
    e = jnp.floor(jnp.log2(jnp.where(absmax > 0, absmax, 1.0)))
    shift = (payload_bits - 1) - e.astype(jnp.int32)
    # ldexp (NOT exp2 — exp2 is inexact on some backends): exact pow2 scaling.
    scaled = jnp.ldexp(x, jnp.broadcast_to(shift, x.shape))
    # Guard against log2 boundary: ensure scaled max strictly < 2**payload_bits.
    too_big = jnp.max(jnp.abs(scaled), axis=ax, keepdims=True) >= 2.0 ** payload_bits
    shift = shift - too_big.astype(jnp.int32)
    scaled = jnp.where(too_big, scaled * 0.5, scaled)
    xi = jnp.round(scaled)
    return xi, jnp.squeeze(shift, axis=ax)


def split_hi_lo(xi: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Exact split of an integer-valued float array into int32 (hi, lo).

    xi = hi * 2**SPLIT_BITS + lo, with |lo| <= 2**(SPLIT_BITS-1) (balanced) and
    |hi| < 2**(53-SPLIT_BITS+1).  Both halves fit int32 for |xi| < 2**53.
    """
    hi_f = jnp.round(xi / SPLIT_RADIX)
    lo_f = xi - hi_f * SPLIT_RADIX
    return hi_f.astype(jnp.int32), lo_f.astype(jnp.int32)


def merge_hi_lo(hi: jax.Array, lo: jax.Array, dtype=jnp.float64) -> jax.Array:
    """Inverse of split_hi_lo (float reconstruction of the exact integer)."""
    return hi.astype(dtype) * float(SPLIT_RADIX) + lo.astype(dtype)


def _balanced_mod(v: jax.Array, m: int) -> jax.Array:
    """Balanced representative of v mod m in int32: range [-(m//2), (m-1)//2]."""
    u = jnp.remainder(v, m)          # canonical [0, m)
    return jnp.where(u > (m - 1) // 2, u - m, u)


def residues_from_hilo(hi: jax.Array, lo: jax.Array, moduli: Sequence[int]) -> jax.Array:
    """Balanced residues (stacked axis 0) of x = hi*2^26 + lo for each modulus.

    Pure int32 arithmetic (TPU-friendly).  Output dtype int8: every balanced residue of
    every modulus <= 256 fits [-128, 127].
    """
    outs = []
    for m in moduli:
        radix_mod = SPLIT_RADIX % m
        v = _balanced_mod(hi, m) * radix_mod + _balanced_mod(lo, m)
        outs.append(_balanced_mod(v, m).astype(jnp.int8))
    return jnp.stack(outs, axis=0)


def residues_direct(xi: jax.Array, moduli: Sequence[int]) -> jax.Array:
    """Oracle path: balanced residues straight from the integer-valued float (via int64).

    Only usable where int64 is available (CPU tests with x64 enabled); the production
    path is residues_from_hilo.
    """
    xl = xi.astype(jnp.int64)
    outs = []
    for m in moduli:
        u = jnp.remainder(xl, m)
        u = jnp.where(u > (m - 1) // 2, u - m, u)
        outs.append(u.astype(jnp.int8))
    return jnp.stack(outs, axis=0)


def apply_unscale(c: jax.Array, shift_rows: jax.Array, shift_cols: jax.Array) -> jax.Array:
    """C = D^{-1} C̃ E^{-1}: undo the exact power-of-two row/col scaling on the output."""
    total = -(shift_rows[:, None] + shift_cols[None, :])
    return jnp.ldexp(c, jnp.broadcast_to(total, c.shape))


def np_split_hi_lo(xi: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Numpy mirror of split_hi_lo for host-side test oracles."""
    hi = np.round(xi / SPLIT_RADIX)
    lo = xi - hi * SPLIT_RADIX
    return hi.astype(np.int64), lo.astype(np.int64)
