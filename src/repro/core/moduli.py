"""Pairwise-coprime moduli selection and Garner (CRT) constants for Ozaki Scheme II.

The paper (Ozaki/Uchino/Imamura 2025, as summarised in Matsuoka 2026 §2.3) requires a
set of pairwise-coprime moduli m_1 < ... < m_r with product M > 2 * max|(Ã B̃)_ij| so the
integer product is uniquely recoverable from its residues.  We use *balanced* residues
(values in [-(m-1)//2 - (m even), (m-1)//2]) so every residue of every modulus <= 256
fits a signed INT8 lane, which is what the TPU MXU int8 path (and the paper's INT8
tensor-core path) consumes.

All constants here are precomputed with exact Python integers and exported as numpy
arrays; downstream JAX code closes over them as compile-time constants.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence, Tuple

import numpy as np

# 2**8 first (exactly the int8 span), then descending odd primes.  Pairwise coprime by
# construction (a power of two plus distinct odd primes).  The first 16 moduli cover
# ~123.7 bits (full 53-bit FP64 payload up to k ~ 2**13); the tail extends coverage to
# k ~ 2**32 for very long contractions.
DEFAULT_MODULI: Tuple[int, ...] = (
    256, 251, 241, 239, 233, 229, 227, 223, 211, 199, 197, 193, 191, 181, 179, 173,
    167, 163, 157, 151,
)

# Split radix for the (hi, lo) int32 representation of the 53-bit scaled integers:
# x = hi * 2**SPLIT_BITS + lo with |lo| <= 2**(SPLIT_BITS-1).  26 keeps |hi| < 2**27
# for |x| < 2**53, so both halves are comfortable int32 values (TPU has no fast int64).
SPLIT_BITS = 26
SPLIT_RADIX = 1 << SPLIT_BITS


def _egcd(a: int, b: int) -> Tuple[int, int, int]:
    if b == 0:
        return a, 1, 0
    g, x, y = _egcd(b, a % b)
    return g, y, x - (a // b) * y


def modinv(a: int, m: int) -> int:
    """Modular inverse of a (mod m); raises if gcd(a, m) != 1."""
    g, x, _ = _egcd(a % m, m)
    if g != 1:
        raise ValueError(f"{a} has no inverse mod {m}")
    return x % m


def check_pairwise_coprime(moduli: Sequence[int]) -> bool:
    for i in range(len(moduli)):
        for j in range(i + 1, len(moduli)):
            if math.gcd(moduli[i], moduli[j]) != 1:
                return False
    return True


def balanced(x: int, m: int) -> int:
    """Balanced representative of x mod m, in [-(m//2), (m-1)//2] (int convention)."""
    v = x % m
    if v > (m - 1) // 2:
        v -= m
    return v


@dataclasses.dataclass(frozen=True)
class GarnerConstants:
    """Precomputed tables for vectorised balanced-digit Garner reconstruction.

    With moduli (m_1..m_r) and prefix products P_j = m_1 * ... * m_{j-1} (P_1 = 1):
      * ``inv_pref[j]``   = P_j^{-1} mod m_j                    (paper eq. (7))
      * ``pref_mod[j,l]`` = P_j mod m_l  (used to update running partial sums)
      * ``pref_f64[j]``   = P_j rounded to float64 (reconstruction weights), and
        ``pref_f64_lo[j]`` the exact double-double tail P_j - fl(P_j), so the
        reconstruction can run in compensated double-double arithmetic and return the
        *correctly rounded* float of the exact integer.
    """

    moduli: Tuple[int, ...]
    inv_pref: np.ndarray       # (r,) int32
    pref_mod: np.ndarray       # (r, r) int32 ; pref_mod[j, l] = P_j mod m_l
    pref_f64: np.ndarray       # (r,) float64
    pref_f64_lo: np.ndarray    # (r,) float64 ; exact tails P_j - fl(P_j)
    prod: int                  # exact M = prod(moduli), python int

    @property
    def r(self) -> int:
        return len(self.moduli)


@functools.lru_cache(maxsize=None)
def garner_constants(moduli: Tuple[int, ...]) -> GarnerConstants:
    if not check_pairwise_coprime(moduli):
        raise ValueError(f"moduli not pairwise coprime: {moduli}")
    r = len(moduli)
    pref = [1] * r
    for j in range(1, r):
        pref[j] = pref[j - 1] * moduli[j - 1]
    inv_pref = np.array([modinv(pref[j], moduli[j]) for j in range(r)], dtype=np.int32)
    pref_mod = np.array(
        [[pref[j] % moduli[l] for l in range(r)] for j in range(r)], dtype=np.int32
    )
    pref_f64 = np.array([float(p) for p in pref], dtype=np.float64)
    pref_f64_lo = np.array([float(p - int(float(p))) for p in pref], dtype=np.float64)
    prod = pref[-1] * moduli[-1]
    return GarnerConstants(
        moduli=tuple(moduli), inv_pref=inv_pref, pref_mod=pref_mod,
        pref_f64=pref_f64, pref_f64_lo=pref_f64_lo, prod=prod,
    )


def capacity_bits(moduli: Sequence[int]) -> float:
    """log2 of the CRT range M = prod(moduli)."""
    return float(sum(math.log2(m) for m in moduli))


def required_r(k: int, payload_bits: int = 53, margin_bits: int = 2,
               moduli: Sequence[int] = DEFAULT_MODULI) -> int:
    """Smallest moduli count r such that prod(m_1..m_r) > 2^margin * k * 2^(2*payload).

    max |(Ã B̃)_ij| <= k * 2^(2*payload); uniqueness of the balanced representative
    needs M > 2*max; margin_bits adds headroom (default: M > 4*max).
    """
    need = 2 * payload_bits + math.ceil(math.log2(max(k, 1))) + margin_bits
    acc = 0.0
    for i, m in enumerate(moduli):
        acc += math.log2(m)
        if acc > need:
            return i + 1
    raise ValueError(
        f"moduli table exhausted: need {need} bits, have {acc:.1f} from {len(moduli)}"
    )


def max_payload_bits(r: int, k: int, margin_bits: int = 2,
                     moduli: Sequence[int] = DEFAULT_MODULI) -> int:
    """Largest per-operand integer width p supported by the first r moduli at length k."""
    cap = capacity_bits(moduli[:r])
    p = int((cap - math.ceil(math.log2(max(k, 1))) - margin_bits - 1e-9) // 2)
    return max(p, 1)
