"""Precision policies — the paper's §8 recommendation made concrete.

"Ozaki-style emulation should be integrated systematically into the standard HPC
libraries and exposed to applications behind precision-policy interfaces."  Every
weight matmul in ``repro.models`` goes through ``Policy.dot``; flipping the policy
swaps the arithmetic between the native MXU paths and the Ozaki emulation paths with
no model-code changes.

Policies:
  bf16        — native mixed precision (bf16 operands, f32 accumulation).  Production
                default; what the dry-run/roofline baselines use.
  fp32        — f32 operands and accumulation.
  fp64        — XLA software float64 (the oracle; CPU tests only — TPU has no FP64
                unit, which is exactly the paper's point).
  ozaki2_int8 — Ozaki Scheme II on the int8 MXU path (CRT, r moduli).
  ozaki2_fp8  — Ozaki Scheme II on the FP8 substrate (§2.4 quantisation trick).
  ozaki1_int8 — Ozaki Scheme I mantissa slicing (S² GEMMs) — the paper's baseline.

Emulated paths carry a custom VJP: the gradient of an FP64-accurate matmul is the
FP64-accurate matmul of the gradients, so emulated training is end-to-end exact (see
examples/fp64_exact_training.py).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import dispatch, ozaki1, ozaki2

POLICIES = ("bf16", "fp32", "fp64", "ozaki2_int8", "ozaki2_fp8", "ozaki1_int8")


def _working_f64():
    """float64 when x64 is live, else float32 (payload auto-clips to 24 bits)."""
    return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32


def _flatten_dot(fn):
    """Lift a 2D (m,k)x(k,n) matmul to (..., k) x (k, n)."""
    @functools.wraps(fn)
    def wrapped(x, w, *a, **kw):
        lead = x.shape[:-1]
        out = fn(x.reshape((-1, x.shape[-1])), w, *a, **kw)
        return out.reshape(lead + (w.shape[-1],))
    return wrapped


# --- differentiable emulated matmul ----------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def ozaki2_dot(a: jax.Array, b: jax.Array, plan: ozaki2.Plan) -> jax.Array:
    return dispatch.matmul(a, b, plan=plan)


def _ozaki2_dot_fwd(a, b, plan):
    return ozaki2_dot(a, b, plan), (a, b)


def _ozaki2_dot_bwd(plan, res, g):
    a, b = res
    # Gradients of C = A B under the same emulated arithmetic:
    #   dA = g B^T, dB = A^T g — contraction length changes, so re-plan
    #   (cache-resolved: the bwd plans are the fwd plans of other layers).
    plan_da = dispatch.get_plan(g.shape[-1], plan.payload_bits,
                                substrate=plan.substrate)
    plan_db = dispatch.get_plan(a.shape[0], plan.payload_bits,
                                substrate=plan.substrate)
    da = dispatch.matmul(g, b.T, plan=plan_da)
    db = dispatch.matmul(a.T, g, plan=plan_db)
    return da.astype(a.dtype), db.astype(b.dtype)


ozaki2_dot.defvjp(_ozaki2_dot_fwd, _ozaki2_dot_bwd)


@jax.custom_vjp
def ozaki1_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return ozaki1.emulated_matmul(a, b, out_dtype=_working_f64())


def _ozaki1_dot_fwd(a, b):
    return ozaki1_dot(a, b), (a, b)


def _ozaki1_dot_bwd(res, g):
    a, b = res
    da = ozaki1.emulated_matmul(g, b.T, out_dtype=_working_f64())
    db = ozaki1.emulated_matmul(a.T, g, out_dtype=_working_f64())
    return da.astype(a.dtype), db.astype(b.dtype)


ozaki1_dot.defvjp(_ozaki1_dot_fwd, _ozaki1_dot_bwd)


# --- the policy object ------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Policy:
    """Dispatches matmuls to a numeric path.  Hashable — safe as a static arg."""

    name: str = "bf16"
    payload_bits: int = 53

    def __post_init__(self):
        if self.name not in POLICIES:
            raise ValueError(f"unknown policy {self.name!r}; choose from {POLICIES}")

    @property
    def is_emulated(self) -> bool:
        return self.name.startswith("ozaki")

    def dot(self, x: jax.Array, w: jax.Array) -> jax.Array:
        """y[..., n] = x[..., k] @ w[k, n] under this policy.

        Output dtype matches x's dtype for the native paths (accumulation in f32);
        emulated paths compute at working-f64 and cast back to x.dtype.
        """
        if self.name == "bf16":
            return jax.lax.dot_general(
                x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                (((x.ndim - 1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32).astype(x.dtype)
        if self.name == "fp32":
            return jax.lax.dot_general(
                x.astype(jnp.float32), w.astype(jnp.float32),
                (((x.ndim - 1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32).astype(x.dtype)
        if self.name == "fp64":
            f64 = _working_f64()
            return jnp.dot(x.astype(f64), w.astype(f64)).astype(x.dtype)
        if self.name in ("ozaki2_int8", "ozaki2_fp8"):
            substrate = self.name.split("_")[1]
            plan = dispatch.get_plan(x.shape[-1], self.payload_bits,
                                     substrate=substrate)
            f64 = _working_f64()
            out = _flatten_dot(ozaki2_dot)(x.astype(f64), w.astype(f64), plan)
            return out.astype(x.dtype)
        if self.name == "ozaki1_int8":
            f64 = _working_f64()
            out = _flatten_dot(ozaki1_dot)(x.astype(f64), w.astype(f64))
            return out.astype(x.dtype)
        raise AssertionError(self.name)

    def matmul_flops_multiplier(self) -> int:
        """TME α for this policy (1 for native paths) — used by the roofline tooling."""
        if self.name in ("bf16", "fp32", "fp64"):
            return 1
        if self.name == "ozaki2_int8":
            return 16          # r at k~4096, p=53
        if self.name == "ozaki2_fp8":
            return 48          # 3r
        if self.name == "ozaki1_int8":
            return 64          # S² at S=8
        raise AssertionError(self.name)


DEFAULT_POLICY = Policy("bf16")
