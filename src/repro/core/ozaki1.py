"""Ozaki Scheme I — mantissa-slicing FP64 emulation (paper §2.2, Table 1).

The original error-free-transformation scheme: decompose A = Σ_p A^(p), B = Σ_q B^(q)
into S slices of b payload bits each and reconstruct C ≈ Σ_{p,q} A^(p) B^(q) — cost
Θ(S²) low-precision GEMMs versus Ozaki II's Θ(r).  Implemented here as the paper's
comparison baseline, with the accumulator-bound slice-width analysis of eq. (3):

    2b + ceil(log2 k) <= w_acc   =>   b* = (w_acc - ceil(log2 k)) // 2

We carry slices as signed integers on the INT8/INT32 path (w_acc = 31) — the
substrate Table 1 shows is *input-bound* rather than accumulator-bound at large k —
and optionally drop the low-significance slice pairs (p + q >= S_keep) the way fast
Ozaki-I implementations do.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import splitting


def slice_width(k: int, w_acc: int = 31, input_bits: int = 7) -> int:
    """Paper eq. (3): max safe payload bits per slice for summation length k."""
    b_star = (w_acc - math.ceil(math.log2(max(k, 2)))) // 2
    return max(1, min(b_star, input_bits))


def slice_count(payload_bits: int, b: int) -> int:
    """Slices needed to cover ``payload_bits`` of mantissa at b bits per slice."""
    return math.ceil(payload_bits / b)


@dataclasses.dataclass(frozen=True)
class Ozaki1Plan:
    slice_bits: int          # b: payload bits per slice
    num_slices: int          # S
    payload_bits: int        # total mantissa bits captured (<= 53)
    full_cross: bool = True  # keep all S² cross terms (True) or triangle cut

    @property
    def num_gemms(self) -> int:
        s = self.num_slices
        return s * s if self.full_cross else s * (s + 1) // 2


def make_plan(k: int, payload_bits: int = 53, w_acc: int = 31,
              input_bits: int = 7, full_cross: bool = True) -> Ozaki1Plan:
    b = slice_width(k, w_acc, input_bits)
    return Ozaki1Plan(slice_bits=b, num_slices=slice_count(payload_bits, b),
                      payload_bits=payload_bits, full_cross=full_cross)


def slice_decompose(x: jax.Array, plan: Ozaki1Plan,
                    scale_axis: int) -> Tuple[jax.Array, jax.Array]:
    """Decompose to (slices int8 (S, *x.shape), shift int32).

    x ≈ 2^{-shift} * Σ_p slices[p] * 2^{(S-1-p)*b}; slice p holds b bits, balanced.
    """
    xi, shift = splitting.scale_to_int(x, plan.payload_bits, axis=scale_axis)
    b, s = plan.slice_bits, plan.num_slices
    slices = []
    rem = xi
    for p in range(s):
        w = 2.0 ** ((s - 1 - p) * b)
        sl = jnp.round(rem / w)
        rem = rem - sl * w
        slices.append(sl.astype(jnp.int32).astype(jnp.int8))
    return jnp.stack(slices, axis=0), shift


def _dot_int8(a8: jax.Array, b8: jax.Array) -> jax.Array:
    return jax.lax.dot_general(a8, b8, (((a8.ndim - 1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


@functools.partial(jax.jit, static_argnames=("plan", "out_dtype"))
def emulated_matmul(a: jax.Array, b: jax.Array, plan: Optional[Ozaki1Plan] = None,
                    out_dtype=jnp.float64) -> jax.Array:
    """C = A @ B via Ozaki I slicing on the INT8/INT32 substrate.

    Θ(S²) int8 GEMMs accumulated into FP64 with per-pair power-of-two weights.
    """
    if plan is None:
        plan = make_plan(a.shape[-1])
    a = a.astype(out_dtype)
    b = b.astype(out_dtype)
    asl, ashift = slice_decompose(a, plan, scale_axis=-1)
    bsl, bshift = slice_decompose(b, plan, scale_axis=0)
    bbits, s = plan.slice_bits, plan.num_slices
    out = jnp.zeros((a.shape[0], b.shape[1]), out_dtype)
    for p in range(s):
        for q in range(s):
            if not plan.full_cross and p + q >= s:
                continue
            w = 2.0 ** ((2 * (s - 1) - p - q) * bbits)
            out = out + _dot_int8(asl[p], bsl[q]).astype(out_dtype) * w
    return splitting.apply_unscale(out, ashift, bshift)
