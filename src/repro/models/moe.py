"""Mixture-of-Experts MLP (deepseek-style fine-grained routing + shared experts).

GSPMD-friendly dense-dispatch formulation (Mesh-TensorFlow lineage): top-k routing
produces a (tokens, experts, capacity) dispatch tensor; expert computation is a
batched einsum over the expert axis, which shards on the ``model``/expert axis of
the mesh (EP).  The all-to-alls appear automatically when tokens are data-sharded
and experts are model-sharded — visible in the dry-run HLO and counted by the
roofline collective term.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoECfg
from repro.core.policy import Policy
from repro.models import layers


def moe_init(key, cfg: ModelConfig) -> Dict:
    m = cfg.moe
    d, dt = cfg.d_model, cfg.param_jnp_dtype
    kr, ke, ks = jax.random.split(key, 3)
    scale = 1.0 / math.sqrt(d)
    params = {
        "router": {"w": jax.random.uniform(kr, (d, m.num_experts), jnp.float32,
                                           -scale, scale)},
        "experts": {
            "wi_gate": jax.random.uniform(
                jax.random.fold_in(ke, 0), (m.num_experts, d, m.d_expert), dt,
                -scale, scale),
            "wi_up": jax.random.uniform(
                jax.random.fold_in(ke, 1), (m.num_experts, d, m.d_expert), dt,
                -scale, scale),
            "wo": jax.random.uniform(
                jax.random.fold_in(ke, 2), (m.num_experts, m.d_expert, d), dt,
                -scale / math.sqrt(m.d_expert / d), scale / math.sqrt(m.d_expert / d)),
        },
    }
    if m.num_shared > 0:
        params["shared"] = layers.mlp_init(ks, d, m.num_shared * m.d_expert, dt,
                                           act=cfg.mlp_act)
    return params


def _topk_gating(logits: jax.Array, m: MoECfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (weights (T,k), indices (T,k), aux_loss scalar)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, idx = jax.lax.top_k(probs, m.top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # load-balancing aux loss (Switch-style) + router z-loss
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], m.num_experts, dtype=jnp.float32),
                  axis=0)
    aux = m.num_experts * jnp.sum(me * ce)
    z = jnp.mean(jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1) ** 2)
    return weights, idx, aux + m.router_zloss * z


def moe_apply(params: Dict, x: jax.Array, cfg: ModelConfig,
              policy: Policy) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (out (B, S, d), aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)

    logits = layers.dense_apply(params["router"], xt.astype(jnp.float32),
                                Policy("fp32"))
    weights, idx, aux = _topk_gating(logits, m)

    capacity = int(math.ceil(T * m.top_k / m.num_experts * m.capacity_factor))
    capacity = max(capacity, m.top_k)

    # dispatch (T, E, C): token t -> slot c of expert e (capacity-truncated).
    # Slot positions are assigned over the FLATTENED (T*k) assignment order so
    # choices of different ranks never collide in a capacity slot.
    onehot = jax.nn.one_hot(idx, m.num_experts, dtype=jnp.float32)   # (T, k, E)
    flat = onehot.reshape(-1, m.num_experts)                         # (T*k, E)
    running = jnp.cumsum(flat, axis=0) - flat                        # earlier count
    pos_tk = jnp.einsum("ne,ne->n", running, flat).reshape(onehot.shape[:2])
    keep = (pos_tk < capacity).astype(jnp.float32)                   # (T, k)
    slot_oh = jax.nn.one_hot(pos_tk.astype(jnp.int32), capacity,
                             dtype=jnp.float32)                      # (T, k, C)
    sel = onehot * keep[:, :, None]                                  # (T, k, E)
    dispatch = jnp.einsum("tke,tkc->tec", sel, slot_oh)
    combine = jnp.einsum("tke,tk,tkc->tec", sel, weights, slot_oh)

    cd = cfg.compute_jnp_dtype
    from repro.distributed.annotate import ann
    expert_in = jnp.einsum("tec,td->ecd", dispatch.astype(cd), xt)   # all-to-all
    expert_in = ann(expert_in, ("expert", None, None))
    gate = jnp.einsum("ecd,edf->ecf", expert_in,
                      params["experts"]["wi_gate"].astype(cd))
    up = jnp.einsum("ecd,edf->ecf", expert_in,
                    params["experts"]["wi_up"].astype(cd))
    h = jax.nn.silu(gate) * up
    eo = jnp.einsum("ecf,efd->ecd", h, params["experts"]["wo"].astype(cd))
    out = jnp.einsum("tec,ecd->td", combine.astype(cd), eo)          # all-to-all

    if m.num_shared > 0:
        out = out + layers.mlp_apply(params["shared"], xt, policy, cfg.mlp_act)
    return out.reshape(B, S, d), aux
