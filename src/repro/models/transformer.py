"""Model assembly: pattern-based block stacks with scan-over-periods weight
stacking (compact HLO for 80-layer models), decoder and encoder-decoder families,
full-sequence forward (train/prefill) and single-token decode with typed caches.

Layer topology = ``cfg.pattern`` repeated ``num_periods`` times (params stacked on
a leading periods axis, mixed via lax.scan) plus an unrolled tail for depths not
divisible by the period (e.g. gemma3's 34 = 6·5 + 4).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import BlockCfg, ModelConfig
from repro.core.policy import Policy
from repro.models import attention, layers, moe, ssm


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, blk: BlockCfg, decoder: bool = True) -> Dict:
    ks = jax.random.split(key, 4)
    dt = cfg.param_jnp_dtype
    p: Dict[str, Any] = {"norm1": layers.rmsnorm_init(cfg.d_model, dt)}
    if blk.mixer == "attn":
        p["mixer"] = attention.attn_init(ks[0], cfg)
    elif blk.mixer == "mamba":
        p["mixer"] = ssm.mamba_init(ks[0], cfg)
    elif blk.mixer == "mlstm":
        p["mixer"] = ssm.mlstm_init(ks[0], cfg)
    elif blk.mixer == "slstm":
        p["mixer"] = ssm.slstm_init(ks[0], cfg)
    else:
        raise ValueError(blk.mixer)
    if cfg.family == "encdec" and decoder:
        p["norm_cross"] = layers.rmsnorm_init(cfg.d_model, dt)
        p["cross"] = attention.attn_init(ks[1], cfg, cross=True)
    if blk.mlp == "dense":
        p["norm2"] = layers.rmsnorm_init(cfg.d_model, dt)
        p["mlp"] = layers.mlp_init(ks[2], cfg.d_model, cfg.d_ff, dt,
                                   act=cfg.mlp_act)
    elif blk.mlp == "moe":
        p["norm2"] = layers.rmsnorm_init(cfg.d_model, dt)
        p["mlp"] = moe.moe_init(ks[2], cfg)
    elif blk.mlp != "none":
        raise ValueError(blk.mlp)
    return p


def block_apply(p: Dict, x: jax.Array, blk: BlockCfg, cfg: ModelConfig,
                policy: Policy, sin, cos, enc_out=None,
                causal: bool = True) -> Tuple[jax.Array, jax.Array]:
    from repro.distributed.annotate import ann
    aux = jnp.zeros((), jnp.float32)
    x = ann(x, ("batch", None, None))
    h = layers.rmsnorm_apply(p["norm1"], x)
    if blk.mixer == "attn":
        mo = attention.attn_apply(p["mixer"], h, cfg, policy, sin, cos,
                                  window=blk.window, causal=causal)
    elif blk.mixer == "mamba":
        mo = ssm.mamba_apply(p["mixer"], h, cfg, policy)
    elif blk.mixer == "mlstm":
        mo = ssm.mlstm_apply(p["mixer"], h, cfg, policy)
    else:
        mo = ssm.slstm_apply(p["mixer"], h, cfg, policy)
    x = x + mo
    if enc_out is not None and "cross" in p:
        hc = layers.rmsnorm_apply(p["norm_cross"], x)
        x = x + attention.cross_attn_apply(p["cross"], hc, enc_out, cfg, policy)
    if blk.mlp == "dense":
        h2 = layers.rmsnorm_apply(p["norm2"], x)
        x = x + layers.mlp_apply(p["mlp"], h2, policy, cfg.mlp_act)
    elif blk.mlp == "moe":
        h2 = layers.rmsnorm_apply(p["norm2"], x)
        mo2, a = moe.moe_apply(p["mlp"], h2, cfg, policy)
        x = x + mo2
        aux = aux + a
    return x, aux


# --- decode ------------------------------------------------------------------

def block_cache_init(cfg: ModelConfig, blk: BlockCfg, batch: int, seq_len: int,
                     enc_seq: int = 0) -> Dict:
    c: Dict[str, Any] = {}
    if blk.mixer == "attn":
        c["kv"] = attention.cache_init(cfg, batch, seq_len, blk.window)
    elif blk.mixer == "mamba":
        c["ssm"] = ssm.mamba_state_init(cfg, batch)
    elif blk.mixer == "mlstm":
        c["ssm"] = ssm.mlstm_state_init(cfg, batch)
    elif blk.mixer == "slstm":
        c["ssm"] = ssm.slstm_state_init(cfg, batch)
    if cfg.family == "encdec" and enc_seq:
        # Cross-attention cache follows compute dtype for the same reason as
        # attention.cache_init: a lower-precision cache makes decode diverge
        # from the teacher-forced forward pass.
        shape = (batch, enc_seq, cfg.num_kv_heads, cfg.head_dim)
        dt = cfg.compute_jnp_dtype
        c["cross_kv"] = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    return c


def block_decode_step(p: Dict, x: jax.Array, cache: Dict, blk: BlockCfg,
                      cfg: ModelConfig, policy: Policy, pos, sin, cos
                      ) -> Tuple[jax.Array, Dict]:
    new_cache = dict(cache)
    h = layers.rmsnorm_apply(p["norm1"], x)
    if blk.mixer == "attn":
        mo, kv = attention.attn_decode_step(p["mixer"], h, cache["kv"], pos, cfg,
                                            policy, sin, cos, window=blk.window)
        new_cache["kv"] = kv
    elif blk.mixer == "mamba":
        mo, st = ssm.mamba_decode_step(p["mixer"], h, cache["ssm"], cfg, policy)
        new_cache["ssm"] = st
    elif blk.mixer == "mlstm":
        mo, st = ssm.mlstm_decode_step(p["mixer"], h, cache["ssm"], cfg, policy)
        new_cache["ssm"] = st
    else:
        mo, st = ssm.slstm_decode_step(p["mixer"], h, cache["ssm"], cfg, policy)
        new_cache["ssm"] = st
    x = x + mo
    if "cross_kv" in cache and "cross" in p:
        hc = layers.rmsnorm_apply(p["norm_cross"], x)
        ck = cache["cross_kv"]
        q = attention._split_heads(
            layers.dense_apply(p["cross"]["wq"], hc, policy),
            cfg.num_heads, cfg.head_dim)
        scores = attention._gqa_scores(q, ck["k"].astype(q.dtype), cfg)
        probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(x.dtype)
        co = attention._gqa_out(probs, ck["v"].astype(x.dtype), cfg)
        x = x + layers.dense_apply(p["cross"]["wo"], co, policy)
    if blk.mlp == "dense":
        h2 = layers.rmsnorm_apply(p["norm2"], x)
        x = x + layers.mlp_apply(p["mlp"], h2, policy, cfg.mlp_act)
    elif blk.mlp == "moe":
        h2 = layers.rmsnorm_apply(p["norm2"], x)
        mo2, _ = moe.moe_apply(p["mlp"], h2, cfg, policy)
        x = x + mo2
    return x, new_cache


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def policy(self) -> Policy:
        return Policy(self.cfg.policy_name)

    # --- init ---------------------------------------------------------------

    def init(self, key) -> Dict:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        params: Dict[str, Any] = {
            "embed": layers.embed_init(keys[0], cfg.vocab_size, cfg.d_model,
                                       cfg.param_jnp_dtype),
            "final_norm": layers.rmsnorm_init(cfg.d_model, cfg.param_jnp_dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = layers.dense_init(
                keys[1], cfg.d_model, cfg.vocab_size, cfg.param_jnp_dtype)

        def init_period(k):
            ks = jax.random.split(k, cfg.period)
            return {f"b{j}": block_init(ks[j], cfg, blk)
                    for j, blk in enumerate(cfg.pattern)}

        if cfg.num_periods > 0:
            pkeys = jax.random.split(keys[2], cfg.num_periods)
            params["stack"] = jax.vmap(init_period)(pkeys)
        for j, blk in enumerate(cfg.tail_blocks):
            params[f"tail{j}"] = block_init(jax.random.fold_in(keys[3], j),
                                            cfg, blk)
        if cfg.family == "encdec":
            ekeys = jax.random.split(keys[4], cfg.encoder_layers)
            eblk = BlockCfg(mixer="attn", mlp="dense")
            params["encoder"] = jax.vmap(
                lambda k: block_init(k, cfg, eblk, decoder=False))(ekeys)
            params["enc_norm"] = layers.rmsnorm_init(cfg.d_model,
                                                     cfg.param_jnp_dtype)
            params["enc_pos"] = jax.random.normal(
                keys[5], (cfg.encoder_seq, cfg.d_model),
                cfg.param_jnp_dtype) * 0.02
        return params

    # --- shared pieces --------------------------------------------------------

    def _rope(self, positions, batch: Optional[int] = None):
        cfg = self.cfg
        if cfg.rope_type == "none":
            s = positions.shape[-1] if positions.ndim else 1
            z = jnp.zeros((s, cfg.head_dim // 2), jnp.float32)
            return z, 1.0 + z
        if cfg.rope_type == "mrope":
            if positions.ndim == 1:  # text-only: all three streams identical
                positions = jnp.broadcast_to(positions[None, None, :],
                                             (batch or 1, 3, positions.shape[0]))
            return layers.mrope_angles(positions, cfg.head_dim, cfg.rope_theta,
                                       cfg.mrope_sections)
        return layers.rope_angles(positions, cfg.head_dim, cfg.rope_theta)

    def _encode(self, params: Dict, enc_embeds: jax.Array) -> jax.Array:
        cfg = self.cfg
        policy = self.policy
        x = enc_embeds.astype(cfg.compute_jnp_dtype)
        x = x + params["enc_pos"].astype(x.dtype)[None, :x.shape[1]]
        sin, cos = self._rope(jnp.arange(x.shape[1]))
        eblk = BlockCfg(mixer="attn", mlp="dense")

        def enc_layer(x, p):
            y, _ = block_apply(p, x, eblk, cfg, policy, sin, cos, causal=False)
            return y

        if cfg.remat:
            enc_layer = jax.checkpoint(enc_layer)

        if cfg.force_unroll:
            for i in range(cfg.encoder_layers):
                x = enc_layer(x, jax.tree.map(lambda t: t[i], params["encoder"]))
        else:
            x, _ = jax.lax.scan(lambda c, p: (enc_layer(c, p), None), x,
                                params["encoder"])
        return layers.rmsnorm_apply(params["enc_norm"], x)

    # --- forward (train / prefill) --------------------------------------------

    def apply(self, params: Dict, batch: Dict) -> Tuple[jax.Array, jax.Array]:
        """batch: {"tokens" (B,S) int32 | "embeds" (B,S,d)} [+ "enc_embeds",
        "positions"]; returns (logits f32 (B,S,V), aux_loss)."""
        cfg = self.cfg
        policy = self.policy
        if "embeds" in batch:
            x = batch["embeds"].astype(cfg.compute_jnp_dtype)
        else:
            x = layers.embed_apply(params["embed"], batch["tokens"],
                                   cfg.compute_jnp_dtype)
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        B, S = x.shape[:2]
        positions = batch.get("positions", jnp.arange(S))
        sin, cos = self._rope(positions, batch=B)
        enc_out = (self._encode(params, batch["enc_embeds"])
                   if cfg.family == "encdec" else None)

        aux = jnp.zeros((), jnp.float32)

        def period_fn(x, aux, pp):
            for j, blk in enumerate(cfg.pattern):
                x, a = block_apply(pp[f"b{j}"], x, blk, cfg, policy, sin, cos,
                                   enc_out=enc_out)
                aux = aux + a
            return x, aux

        if cfg.remat:
            period_fn = jax.checkpoint(period_fn)

        def period_body(carry, pp):
            x, aux = carry
            x, aux = period_fn(x, aux, pp)
            return (x, aux), None

        if cfg.num_periods > 0:
            if cfg.force_unroll:
                for i in range(cfg.num_periods):
                    pp = jax.tree.map(lambda t: t[i], params["stack"])
                    x, aux = period_fn(x, aux, pp)
            else:
                (x, aux), _ = jax.lax.scan(period_body, (x, aux),
                                           params["stack"])
        for j, blk in enumerate(cfg.tail_blocks):
            x, a = block_apply(params[f"tail{j}"], x, blk, cfg, policy, sin, cos,
                               enc_out=enc_out)
            aux = aux + a

        from repro.distributed.annotate import ann
        x = layers.rmsnorm_apply(params["final_norm"], x)
        if cfg.tie_embeddings:
            logits = layers.unembed_apply(params["embed"], x, policy)
        else:
            logits = ann(layers.dense_apply(params["lm_head"], x,
                                            policy).astype(jnp.float32),
                         ("batch", None, "vocab"))
        logits = layers.softcap(logits, cfg.logit_softcap)
        return logits, aux

    # --- decode ----------------------------------------------------------------

    def init_cache(self, batch: int, seq_len: int) -> Dict:
        cfg = self.cfg
        cache: Dict[str, Any] = {}

        def one_period(_):
            return {f"b{j}": block_cache_init(cfg, blk, batch, seq_len,
                                              enc_seq=cfg.encoder_seq)
                    for j, blk in enumerate(cfg.pattern)}

        if cfg.num_periods > 0:
            cache["stack"] = jax.vmap(one_period)(jnp.arange(cfg.num_periods))
        for j, blk in enumerate(cfg.tail_blocks):
            cache[f"tail{j}"] = block_cache_init(cfg, blk, batch, seq_len,
                                                 enc_seq=cfg.encoder_seq)
        return cache

    def decode_step(self, params: Dict, cache: Dict, tokens: jax.Array,
                    pos: jax.Array) -> Tuple[jax.Array, Dict]:
        """tokens (B, 1) int32; pos scalar int32.  Returns (logits (B,1,V), cache)."""
        cfg = self.cfg
        policy = self.policy
        x = layers.embed_apply(params["embed"], tokens, cfg.compute_jnp_dtype)
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        B = x.shape[0]
        positions = jnp.full((1,), pos, jnp.int32)
        sin, cos = self._rope(positions, batch=B)

        new_cache: Dict[str, Any] = {}

        def period_body(x, xs):
            pp, cc = xs
            ncc = {}
            for j, blk in enumerate(cfg.pattern):
                x, nc = block_decode_step(pp[f"b{j}"], x, cc[f"b{j}"], blk, cfg,
                                          policy, pos, sin, cos)
                ncc[f"b{j}"] = nc
            return x, ncc

        if cfg.num_periods > 0:
            if cfg.force_unroll:
                nccs = []
                for i in range(cfg.num_periods):
                    pp = jax.tree.map(lambda t: t[i], params["stack"])
                    cc = jax.tree.map(lambda t: t[i], cache["stack"])
                    x, ncc = period_body(x, (pp, cc))
                    nccs.append(ncc)
                new_cache["stack"] = jax.tree.map(
                    lambda *ts: jnp.stack(ts), *nccs)
            else:
                x, new_cache["stack"] = jax.lax.scan(
                    period_body, x, (params["stack"], cache["stack"]))
        for j, blk in enumerate(cfg.tail_blocks):
            x, nc = block_decode_step(params[f"tail{j}"], x, cache[f"tail{j}"],
                                      blk, cfg, policy, pos, sin, cos)
            new_cache[f"tail{j}"] = nc

        x = layers.rmsnorm_apply(params["final_norm"], x)
        if cfg.tie_embeddings:
            logits = layers.unembed_apply(params["embed"], x, policy)
        else:
            logits = layers.dense_apply(params["lm_head"], x,
                                        policy).astype(jnp.float32)
        return layers.softcap(logits, cfg.logit_softcap), new_cache
