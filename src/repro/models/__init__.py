"""repro.models subpackage."""
