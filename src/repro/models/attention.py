"""GQA attention with sliding windows, cross-attention, and ring-buffer KV caches.

Shapes: activations (B, S, d_model); q (B, S, H, D); k/v (B, S, Hkv, D).
GQA groups H // Hkv query heads per KV head.  Sliding-window layers keep a cache
of only ``window`` positions (ring buffer) — this is what makes gemma3's
long_500k decode cell memory-feasible (DESIGN.md §5).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import dispatch
from repro.core.policy import Policy
from repro.distributed.annotate import ann
from repro.models import layers

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig, cross: bool = False) -> Dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, dt = cfg.d_model, cfg.param_jnp_dtype
    return {
        "wq": layers.dense_init(kq, d, cfg.num_heads * cfg.head_dim, dt),
        "wk": layers.dense_init(kk, d, cfg.num_kv_heads * cfg.head_dim, dt),
        "wv": layers.dense_init(kv, d, cfg.num_kv_heads * cfg.head_dim, dt),
        "wo": layers.dense_init(ko, cfg.num_heads * cfg.head_dim, d, dt),
    }


def _split_heads(x: jax.Array, n: int, d: int) -> jax.Array:
    return x.reshape(x.shape[:-1] + (n, d))


def _qkv(params: Dict, x: jax.Array, kv_x: jax.Array, cfg: ModelConfig,
         policy: Policy):
    q = _split_heads(layers.dense_apply(params["wq"], x, policy),
                     cfg.num_heads, cfg.head_dim)
    k = _split_heads(layers.dense_apply(params["wk"], kv_x, policy),
                     cfg.num_kv_heads, cfg.head_dim)
    v = _split_heads(layers.dense_apply(params["wv"], kv_x, policy),
                     cfg.num_kv_heads, cfg.head_dim)
    # Never let GSPMD shard head_dim into the score contraction (DESIGN.md §5):
    # q-heads on "model" when divisible, else context-parallel (seq on "model").
    q = ann(q, ("batch", "aseq", "heads", None))
    k = ann(k, ("batch", None, "kv_heads", None))
    v = ann(v, ("batch", None, "kv_heads", None))
    return q, k, v


def _gqa_scores(q: jax.Array, k: jax.Array, cfg: ModelConfig) -> jax.Array:
    """(B,S,H,D) x (B,T,Hkv,D) -> (B, Hkv, H/Hkv, S, T)."""
    g = cfg.num_heads // cfg.num_kv_heads
    B, S = q.shape[0], q.shape[1]
    qg = q.reshape(B, S, cfg.num_kv_heads, g, cfg.head_dim)
    return jnp.einsum("bsngd,btnd->bngst", qg, k) / math.sqrt(cfg.head_dim)


def _gqa_out(probs: jax.Array, v: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, _, g, S, _ = probs.shape
    out = jnp.einsum("bngst,btnd->bsngd", probs, v)
    return out.reshape(B, S, cfg.num_heads * cfg.head_dim)


def _causal_window_mask(s: int, t: int, window: int, offset: int = 0) -> jax.Array:
    """Mask (s, t): query i (absolute pos i+offset) attends to key j iff
    j <= i+offset and (window == 0 or i+offset - j < window)."""
    qpos = jnp.arange(s)[:, None] + offset
    kpos = jnp.arange(t)[None, :]
    ok = kpos <= qpos
    if window > 0:
        ok &= (qpos - kpos) < window
    return ok


def _emulated_attn(q, k, v, cfg: ModelConfig, mask, dtype) -> jax.Array:
    """GQA attention through the dispatch seam's fused ``attention`` kind.

    q: (B, S, H, D); k/v: (B, T, Hkv, D); mask: (S, T) shared across the
    batch (or None = attend to all).  Queries are grouped per KV head and
    flattened to (B·Hkv·g, S, D) rows so each row is one independent
    softmax-attention problem for ``dispatch.attention`` — the seam routes it
    to the fused online-softmax Pallas kernel or the bit-identical reference
    per ``REPRO_DISPATCH``/``mode_scope``, with softcap/scale/mask order
    matching the native ``_attn_direct`` path.
    """
    B, S, H, D = q.shape
    T = k.shape[1]
    n = cfg.num_kv_heads
    g = H // n
    qf = jnp.moveaxis(q, 2, 1).reshape(B * n * g, S, D)
    kf = jnp.broadcast_to(jnp.moveaxis(k, 2, 1)[:, :, None],
                          (B, n, g, T, D)).reshape(B * n * g, T, D)
    vf = jnp.broadcast_to(jnp.moveaxis(v, 2, 1)[:, :, None],
                          (B, n, g, T, D)).reshape(B * n * g, T, D)
    out = dispatch.attention(qf, kf, vf, mask=mask,
                             softcap=float(cfg.logit_softcap))
    out = jnp.moveaxis(out.reshape(B, H, S, D), 1, 2)
    return out.reshape(B, S, H * D).astype(dtype)


def _attn_direct(q, k, v, cfg: ModelConfig, window: int, causal: bool,
                 dtype) -> jax.Array:
    scores = _gqa_scores(q, k, cfg).astype(jnp.float32)
    scores = layers.softcap(scores, cfg.logit_softcap)
    if causal:
        mask = _causal_window_mask(q.shape[1], k.shape[1], window)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
    B, S = q.shape[0], q.shape[1]
    out = jnp.einsum("bngst,btnd->bsngd",
                     probs.reshape(B, cfg.num_kv_heads,
                                   cfg.num_heads // cfg.num_kv_heads, S, -1),
                     v)
    return out.reshape(B, S, cfg.num_heads * cfg.head_dim)


def _attn_chunked(q, k, v, cfg: ModelConfig, window: int, dtype,
                  chunk: int, unroll: bool) -> jax.Array:
    """Flash-style online-softmax over q-blocks: peak activation is
    O(chunk * T) per head instead of O(S * T).  Causal only (train/prefill)."""
    B, S, H, D = q.shape
    n = cfg.num_kv_heads
    g = H // n
    nchunks = S // chunk
    qb = q.reshape(B, nchunks, chunk, H, D)
    scale = 1.0 / math.sqrt(D)

    def one_chunk(ci, qc):
        # qc: (B, chunk, H, D); keys/values full (B, T, n, D)
        qg = qc.reshape(B, chunk, n, g, D)
        s = jnp.einsum("bsngd,btnd->bngst", qg, k).astype(jnp.float32) * scale
        s = layers.softcap(s, cfg.logit_softcap)
        mask = _causal_window_mask(chunk, k.shape[1], window, offset=ci * chunk)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(dtype)
        o = jnp.einsum("bngst,btnd->bsngd", p, v)
        return o.reshape(B, chunk, H * D)

    # per-chunk remat: backward recomputes one chunk's scores at a time, so the
    # live set is O(chunk*T) regardless of how many chunks the map saves.
    one_chunk = jax.checkpoint(one_chunk, static_argnums=())

    if unroll:
        outs = [one_chunk(ci, qb[:, ci]) for ci in range(nchunks)]
        return jnp.stack(outs, 1).reshape(B, S, H * D)
    out = jax.lax.map(lambda args: one_chunk(*args),
                      (jnp.arange(nchunks), jnp.moveaxis(qb, 1, 0)))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H * D)


def attn_apply(params: Dict, x: jax.Array, cfg: ModelConfig, policy: Policy,
               sin: jax.Array, cos: jax.Array, window: int = 0,
               causal: bool = True) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    q, k, v = _qkv(params, x, x, cfg, policy)
    if cfg.rope_type != "none":
        q = layers.apply_rope(q, sin, cos)
        k = layers.apply_rope(k, sin, cos)
    S = q.shape[1]
    if policy.is_emulated:
        # Paper-faithful policies put the whole score path on the dispatch
        # seam (kind "attention"): fused online-softmax scan or bit-identical
        # reference per the ambient mode, instead of the native einsum paths.
        mask = (_causal_window_mask(S, k.shape[1], window) if causal
                else jnp.ones((S, k.shape[1]), jnp.bool_))
        attn_out = _emulated_attn(q, k, v, cfg, mask, x.dtype)
    elif causal and cfg.attn_chunk and S > cfg.attn_chunk and \
            S % cfg.attn_chunk == 0:
        attn_out = _attn_chunked(q, k, v, cfg, window, x.dtype,
                                 cfg.attn_chunk, cfg.force_unroll)
    else:
        attn_out = _attn_direct(q, k, v, cfg, window, causal, x.dtype)
    return layers.dense_apply(params["wo"], attn_out, policy)


def cross_attn_apply(params: Dict, x: jax.Array, enc_out: jax.Array,
                     cfg: ModelConfig, policy: Policy) -> jax.Array:
    """Encoder-decoder cross attention (no RoPE, no mask)."""
    q, k, v = _qkv(params, x, enc_out, cfg, policy)
    if policy.is_emulated:
        attn_out = _emulated_attn(q, k, v, cfg, None, x.dtype)
        return layers.dense_apply(params["wo"], attn_out, policy)
    scores = _gqa_scores(q, k, cfg).astype(jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    return layers.dense_apply(params["wo"], _gqa_out(probs, v, cfg), policy)


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------

def cache_init(cfg: ModelConfig, batch: int, seq_len: int, window: int,
               dtype=None) -> Dict:
    """Ring-buffer cache: capacity = window for sliding layers else seq_len.

    The cache dtype follows the model's compute dtype (bfloat16 in the
    production configs).  It used to be hard-coded bfloat16, which silently
    quantised K/V during decode while the teacher-forced forward pass kept
    full compute precision — a ~1e-2 per-score perturbation that MoE top-k
    routing amplified into 0.1–0.35 logit flips at near-tied expert
    boundaries (the old decode-vs-forward xfails).  With the cache in
    compute dtype, decode is bit-identical to forward.
    """
    if dtype is None:
        dtype = cfg.compute_jnp_dtype
    cap = min(window, seq_len) if window > 0 else seq_len
    shape = (batch, cap, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_decode_step(params: Dict, x: jax.Array, cache: Dict, pos: jax.Array,
                     cfg: ModelConfig, policy: Policy, sin: jax.Array,
                     cos: jax.Array, window: int = 0) -> Tuple[jax.Array, Dict]:
    """One-token decode: x (B, 1, d); pos scalar int32 (current position).

    The KV cache is a ring buffer of capacity C (= window or full seq); the new
    K/V is written at pos % C; queries attend to all valid slots with the ring
    distance mask.
    """
    q, k, v = _qkv(params, x, x, cfg, policy)
    if cfg.rope_type != "none":
        q = layers.apply_rope(q, sin, cos)
        k = layers.apply_rope(k, sin, cos)
    cap = cache["k"].shape[1]
    slot = (pos % cap).astype(jnp.int32)
    # one-hot masked write instead of dynamic_update_slice: elementwise, so it
    # stays LOCAL under a sequence-sharded cache (a dynamic slice on a sharded
    # dim makes GSPMD reshuffle the whole cache through all-to-alls — measured
    # at 688 GB/step on the gemma3 long_500k cell; see EXPERIMENTS.md §Perf).
    sel = (jnp.arange(cap) == slot).astype(cache["k"].dtype)[None, :, None, None]
    ck = cache["k"] * (1 - sel) + k.astype(cache["k"].dtype) * sel
    cv = cache["v"] * (1 - sel) + v.astype(cache["v"].dtype) * sel
    # slot j holds absolute position p_j = j + cap * floor over ring history;
    # valid iff p_j <= pos and pos - p_j < cap (ring) and p_j within window.
    j = jnp.arange(cap)
    # absolute position currently stored in slot j:
    pj = jnp.where(j <= slot, pos - slot + j, pos - slot + j - cap)
    ok = (pj >= 0) & (pj <= pos)
    if window > 0:
        ok &= (pos - pj) < window
    # Long-context (batch=1) decode: keep the cache sequence-sharded through
    # the attention math (partial softmax reductions are tiny vs gathering the
    # cache — §Perf H2 measured 248 GB/step otherwise).  Only applied when the
    # launcher installs a "kvseq" mapping: a PartitionSpec None dim *forces*
    # replication, which would regress the batch-sharded decode cells.
    from repro.distributed.annotate import rule_set
    if policy.is_emulated and not rule_set("kvseq"):
        # Decode rides the same dispatch kind as prefill, with the ring
        # validity mask as the (1, cap) padding mask — telemetry sees it as
        # the "decode" shape class (S = 1).
        attn_out = _emulated_attn(q, ck.astype(q.dtype), cv.astype(q.dtype),
                                  cfg, ok[None, :], x.dtype)
        out = layers.dense_apply(params["wo"], attn_out, policy)
        return out, {"k": ck, "v": cv}
    if rule_set("kvseq"):
        # batch is 1 in this regime — never mapped (duplicate-axis hazard)
        ck = ann(ck, (None, "kvseq", "kv_heads", None))
        cv = ann(cv, (None, "kvseq", "kv_heads", None))
        scores = _gqa_scores(q, ck.astype(q.dtype), cfg).astype(jnp.float32)
        scores = ann(scores, (None, "kv_heads", None, None, "kvseq"))
    else:
        scores = _gqa_scores(q, ck.astype(q.dtype), cfg).astype(jnp.float32)
    scores = layers.softcap(scores, cfg.logit_softcap)
    scores = jnp.where(ok[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = layers.dense_apply(params["wo"],
                             _gqa_out(probs, cv.astype(x.dtype), cfg), policy)
    return out, {"k": ck, "v": cv}
