"""Core layers (functional style — params are plain dict pytrees).

Every weight matmul routes through the precision policy (repro.core.policy), which
is how the paper's technique becomes a first-class framework feature: the same
model runs on the native bf16 MXU path or at FP64-equivalent accuracy on the
Ozaki-II int8/fp8 path by flipping ``ModelConfig.policy_name``.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import Policy
from repro.distributed.annotate import ann


# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> Dict:
    scale = 1.0 / math.sqrt(d_in)
    return {"w": jax.random.uniform(key, (d_in, d_out), dtype, -scale, scale)}


def dense_apply(params: Dict, x: jax.Array, policy: Policy) -> jax.Array:
    return policy.dot(x, params["w"].astype(x.dtype))


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Dict:
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm_apply(params: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype, act: str = "swiglu") -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "wi_up": dense_init(k2, d_model, d_ff, dtype),
        "wo": dense_init(k3, d_ff, d_model, dtype),
    }
    if act in ("swiglu", "geglu"):
        p["wi_gate"] = dense_init(k1, d_model, d_ff, dtype)
    return p


def mlp_apply(params: Dict, x: jax.Array, policy: Policy,
              act: str = "swiglu") -> jax.Array:
    # batch stays data-sharded; hidden is model-sharded (Megatron col->row).
    # The constraints force GSPMD into FSDP weight-gathering rather than
    # batch-replicating partial-sum plans (see DESIGN.md §5).  Rank-adaptive:
    # MoE shared experts call this on flattened (tokens, d) activations.
    mid = (None,) * (x.ndim - 2)
    up = ann(dense_apply(params["wi_up"], x, policy), ("batch",) + mid + ("ff",))
    if act == "swiglu":
        h = jax.nn.silu(dense_apply(params["wi_gate"], x, policy)) * up
    elif act == "geglu":
        h = jax.nn.gelu(dense_apply(params["wi_gate"], x, policy),
                        approximate=True) * up
    elif act == "relu2":        # minitron/nemotron squared-ReLU, non-gated
        h = jnp.square(jax.nn.relu(up))
    else:
        raise ValueError(act)
    return ann(dense_apply(params["wo"], h, policy),
               ("batch",) + mid + (None,))


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d: int, dtype) -> Dict:
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed_apply(params: Dict, tokens: jax.Array, compute_dtype) -> jax.Array:
    return ann(params["table"].astype(compute_dtype)[tokens],
               ("batch", None, None))


def unembed_apply(params: Dict, x: jax.Array, policy: Policy) -> jax.Array:
    """Logits = x @ table^T (tied) — f32 output for a stable softmax/xent."""
    logits = policy.dot(x, params["table"].astype(x.dtype).T).astype(jnp.float32)
    return ann(logits, ("batch", None, "vocab"))


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (..., S) -> (sin, cos) of shape (..., S, head_dim // 2), f32."""
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.sin(ang), jnp.cos(ang)


def mrope_angles(positions3: jax.Array, head_dim: int, theta: float,
                 sections: Tuple[int, int, int]) -> Tuple[jax.Array, jax.Array]:
    """Qwen2-VL M-RoPE: three position streams (t, h, w) own disjoint frequency
    sections of the rotary half-space.  positions3: (B, 3, S)."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=half)           # (half,) stream owner
    p = positions3.astype(jnp.float32)                      # (B, 3, S)
    pos_per_freq = p[:, sec_id, :]                          # (B, half, S)
    ang = jnp.swapaxes(pos_per_freq, 1, 2) * inv_freq       # (B, S, half)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (B, S, H, D); sin/cos: (B, S, D//2) or (S, D//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == 2:
        sin = sin[None]
        cos = cos[None]
    s = sin[:, :, None, :].astype(x.dtype)
    c = cos[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits
