"""State-space and recurrent mixers: Mamba (jamba) and xLSTM (sLSTM / mLSTM).

All are attention-free, state-carrying mixers, which is what makes the hybrid /
SSM architectures runnable at the long_500k decode shape: decode state is O(1) in
sequence length (DESIGN.md §5).

Memory discipline (the production-framework part):
  * Mamba: time is processed in ``ssm_chunk`` blocks — an outer sequential scan
    carries the (B, d_inner, d_state) boundary state, an inner associative scan
    parallelises within the chunk.  Peak activation is O(B·chunk·d_inner·d_state)
    instead of O(B·S·d_inner·d_state).
  * mLSTM / sLSTM: outer chunk scan + inner step scan; with per-period remat the
    backward pass re-runs one chunk at a time, so the per-step matrix-memory
    residuals (B,H,dh,dh) are only ever live for ``lstm_chunk`` steps.
  * sLSTM uses head-blocked recurrence (R is block-diagonal per head, as in the
    xLSTM paper) — the head axis shards on "model" with no collectives inside
    the time loop.

Under ``cfg.force_unroll`` (dry-run cost extraction) the outer chunk loops are
Python loops, so XLA's once-per-while-body cost analysis sees every chunk.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import Policy
from repro.models import layers


def _chunk_scan(chunk_fn, init_state, xs_tree, nchunks: int, unroll: bool,
                remat: bool):
    """Outer sequential scan over time-chunks.  xs_tree leaves: (B, nchunks, ...)."""
    fn = jax.checkpoint(chunk_fn) if remat else chunk_fn
    if unroll:
        state = init_state
        outs = []
        for c in range(nchunks):
            xc = jax.tree.map(lambda t: t[:, c], xs_tree)
            state, yc = fn(state, xc)
            outs.append(yc)
        return state, jnp.stack(outs, axis=1)
    xs_t = jax.tree.map(lambda t: jnp.moveaxis(t, 1, 0), xs_tree)
    state, ys = jax.lax.scan(lambda s, xc: fn(s, xc), init_state, xs_t)
    return state, jnp.moveaxis(ys, 0, 1)


# ---------------------------------------------------------------------------
# Mamba (selective SSM)
# ---------------------------------------------------------------------------

def mamba_init(key, cfg: ModelConfig) -> Dict:
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state_dim
    dt = cfg.param_jnp_dtype
    ks = jax.random.split(key, 7)
    return {
        "in_proj": layers.dense_init(ks[0], d, 2 * di, dt),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv_width, di), dt) * 0.1,
        "x_proj": layers.dense_init(ks[2], di, 2 * ds + 1, dt),  # B, C, dt
        "dt_bias": jnp.zeros((di,), dt),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, 1))),
        "d_skip": jnp.ones((di,), dt),
        "out_proj": layers.dense_init(ks[3], di, d, dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state=None):
    """Depthwise causal conv over time.  x (B, S, di); w (K, di)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (K - 1,) + x.shape[2:], x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)               # (B, S+K-1, di)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))
    return y, xp[:, -(K - 1):]


def _mamba_bcd(params, xin, cfg):
    ds = cfg.ssm_state_dim
    bcd = xin.astype(jnp.float32)
    Bm, Cm, dt_raw = bcd[..., :ds], bcd[..., ds:2 * ds], bcd[..., -1:]
    dt = jax.nn.softplus(
        dt_raw + params["dt_bias"].astype(jnp.float32)[..., :1].mean())
    return Bm, Cm, dt


def mamba_apply(params: Dict, x: jax.Array, cfg: ModelConfig,
                policy: Policy) -> jax.Array:
    """Chunked selective scan: outer chunk recurrence + inner associative scan."""
    B, S, _ = x.shape
    di, ds = cfg.d_inner, cfg.ssm_state_dim
    chunk = min(cfg.ssm_chunk, S)
    if S % chunk:
        chunk = S
    nchunks = S // chunk

    xz = layers.dense_apply(params["in_proj"], x, policy)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin, _ = _causal_conv(xin, params["conv_w"].astype(x.dtype))
    xin = jax.nn.silu(xin)
    bcd = layers.dense_apply(params["x_proj"], xin, policy)
    Bm, Cm, dt = _mamba_bcd(params, bcd, cfg)
    A = -jnp.exp(params["a_log"])                          # (di, ds)
    xf32 = xin.astype(jnp.float32)

    dA = jnp.exp(dt[..., None] * A[None, None])            # (B,S,di,ds)
    dBx = (dt * xf32)[..., None] * Bm[:, :, None, :]       # (B,S,di,ds)

    def combine(a, b):
        return a[0] * b[0], b[0] * a[1] + b[1]

    def chunk_fn(h0, xc):
        dAc, dBxc, Cc = xc                                  # (B,chunk,di,ds), (B,chunk,ds)
        gA, gB = jax.lax.associative_scan(combine, (dAc, dBxc), axis=1)
        h = gA * h0[:, None] + gB                           # inject boundary state
        y = jnp.einsum("bsdn,bsn->bsd", h, Cc)
        return h[:, -1], y

    def rs(t):
        return t.reshape(B, nchunks, chunk, *t.shape[2:])

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    _, y = _chunk_scan(chunk_fn, h0, (rs(dA), rs(dBx), rs(Cm)), nchunks,
                       unroll=cfg.force_unroll, remat=cfg.remat)
    y = y.reshape(B, S, di)
    y = y + xf32 * params["d_skip"].astype(jnp.float32)[None, None]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return layers.dense_apply(params["out_proj"], y, policy)


def mamba_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state_dim), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, cfg.d_inner), dtype),
    }


def mamba_decode_step(params: Dict, x: jax.Array, state: Dict, cfg: ModelConfig,
                      policy: Policy) -> Tuple[jax.Array, Dict]:
    """One-token recurrent update.  x (B, 1, d)."""
    xz = layers.dense_apply(params["in_proj"], x, policy)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin, conv_state = _causal_conv(xin, params["conv_w"].astype(x.dtype),
                                   state["conv"])
    xin = jax.nn.silu(xin)
    bcd = layers.dense_apply(params["x_proj"], xin, policy)
    Bm, Cm, dt = _mamba_bcd(params, bcd, cfg)
    A = -jnp.exp(params["a_log"])
    xf = xin.astype(jnp.float32)[:, 0]                      # (B, di)
    dA = jnp.exp(dt[:, 0, :, None] * A[None])               # (B,di,ds)
    dBx = (dt[:, 0] * xf)[..., None] * Bm[:, 0, None, :]
    h = state["h"] * dA + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])
    y = y + xf * params["d_skip"].astype(jnp.float32)[None]
    y = (y * jax.nn.silu(z.astype(jnp.float32)[:, 0]))[:, None].astype(x.dtype)
    out = layers.dense_apply(params["out_proj"], y, policy)
    return out, {"h": h, "conv": conv_state.astype(state["conv"].dtype)}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory, head-blocked)
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig) -> Dict:
    d, di = cfg.d_model, cfg.d_inner
    H = cfg.num_heads
    dt = cfg.param_jnp_dtype
    ks = jax.random.split(key, 6)
    return {
        "up_proj": layers.dense_init(ks[0], d, 2 * di, dt),
        "wq": layers.dense_init(ks[1], di, di, dt),
        "wk": layers.dense_init(ks[2], di, di, dt),
        "wv": layers.dense_init(ks[3], di, di, dt),
        "w_if": layers.dense_init(ks[4], di, 2 * H, dt),    # input/forget gates
        "down_proj": layers.dense_init(ks[5], di, d, dt),
    }


def _mlstm_heads(x, H):
    B, S, di = x.shape
    return x.reshape(B, S, H, di // H)


def _mlstm_step(carry, inp, scale):
    C, n, m = carry                                  # (B,H,dh,dh),(B,H,dh),(B,H)
    qt, kt, vt, it, ft = inp
    m_new = jnp.maximum(ft + m, it)                  # stabilised exp gating
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(ft + m - m_new)
    C = f_[..., None, None] * C + i_[..., None, None] * (
        kt[..., :, None] * vt[..., None, :])
    n = f_[..., None] * n + i_[..., None] * kt
    num = jnp.einsum("bhd,bhde->bhe", qt * scale, C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qt * scale, n))
    h = num / jnp.maximum(den, 1.0)[..., None]
    return (C, n, m_new), h


def mlstm_apply(params: Dict, x: jax.Array, cfg: ModelConfig,
                policy: Policy) -> jax.Array:
    """Chunked mLSTM: outer chunk scan (remat boundary) + inner step scan."""
    H = cfg.num_heads
    B, S, _ = x.shape
    up = layers.dense_apply(params["up_proj"], x, policy)
    xi, z = jnp.split(up, 2, axis=-1)
    q = _mlstm_heads(layers.dense_apply(params["wq"], xi, policy), H)
    k = _mlstm_heads(layers.dense_apply(params["wk"], xi, policy), H)
    v = _mlstm_heads(layers.dense_apply(params["wv"], xi, policy), H)
    gates = layers.dense_apply(params["w_if"], xi, policy).astype(jnp.float32)
    ig, fg = jnp.split(gates, 2, axis=-1)                   # (B,S,H)
    dh = q.shape[-1]
    scale = 1.0 / math.sqrt(dh)

    chunk = min(cfg.lstm_chunk, S)
    if S % chunk:
        chunk = S
    nchunks = S // chunk

    def chunk_fn(carry, xc):
        qc, kc, vc, ic, fc = xc                             # (B, chunk, ...)
        def step(c, inp):
            return _mlstm_step(c, inp, scale)
        carry, hs = jax.lax.scan(
            step, carry,
            (qc.swapaxes(0, 1).astype(jnp.float32),
             kc.swapaxes(0, 1).astype(jnp.float32),
             vc.swapaxes(0, 1).astype(jnp.float32),
             ic.swapaxes(0, 1), fc.swapaxes(0, 1)))
        return carry, hs.swapaxes(0, 1)                     # (B, chunk, H, dh)

    def rs(t):
        return t.reshape(B, nchunks, chunk, *t.shape[2:])

    init = (jnp.zeros((B, H, dh, dh), jnp.float32),
            jnp.zeros((B, H, dh), jnp.float32),
            jnp.zeros((B, H), jnp.float32))
    _, hs = _chunk_scan(chunk_fn, init, (rs(q), rs(k), rs(v), rs(ig), rs(fg)),
                        nchunks, unroll=cfg.force_unroll, remat=cfg.remat)
    h = hs.reshape(B, S, -1).astype(x.dtype)
    h = h * jax.nn.silu(z)
    return layers.dense_apply(params["down_proj"], h, policy)


def mlstm_state_init(cfg: ModelConfig, batch: int) -> Dict:
    H = cfg.num_heads
    dh = cfg.d_inner // H
    return {"C": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
            "m": jnp.zeros((batch, H), jnp.float32)}


def mlstm_decode_step(params: Dict, x: jax.Array, state: Dict, cfg: ModelConfig,
                      policy: Policy) -> Tuple[jax.Array, Dict]:
    H = cfg.num_heads
    up = layers.dense_apply(params["up_proj"], x, policy)
    xi, z = jnp.split(up, 2, axis=-1)
    q = _mlstm_heads(layers.dense_apply(params["wq"], xi, policy), H)[:, 0]
    k = _mlstm_heads(layers.dense_apply(params["wk"], xi, policy), H)[:, 0]
    v = _mlstm_heads(layers.dense_apply(params["wv"], xi, policy), H)[:, 0]
    gates = layers.dense_apply(params["w_if"], xi, policy).astype(jnp.float32)[:, 0]
    it, ft = jnp.split(gates, 2, axis=-1)
    dh = q.shape[-1]
    (C, n, m), h = _mlstm_step(
        (state["C"], state["n"], state["m"]),
        (q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
         it, ft), 1.0 / math.sqrt(dh))
    h = h.reshape(x.shape[0], 1, -1).astype(x.dtype) * jax.nn.silu(z)
    out = layers.dense_apply(params["down_proj"], h, policy)
    return out, {"C": C, "n": n, "m": m}


def slstm_init(key, cfg: ModelConfig) -> Dict:
    d, di = cfg.d_model, cfg.d_inner
    H = cfg.num_heads
    dh = di // H
    dt = cfg.param_jnp_dtype
    ks = jax.random.split(key, 3)
    scale = 1.0 / math.sqrt(dh)
    return {
        "w_in": layers.dense_init(ks[0], d, 4 * di, dt),    # i, f, z, o pre-acts
        # head-blocked recurrence (xLSTM block-diagonal R): (H, dh, 4*dh)
        "r_blocks": jax.random.uniform(ks[1], (H, dh, 4 * dh), dt,
                                       -scale, scale),
        "down_proj": layers.dense_init(ks[2], di, d, dt),
    }


def _slstm_step(carry, wx, r_blocks):
    """wx: (B, H, 4*dh) input pre-activations; carry h: (B, H, dh)."""
    c, n, m, h_prev = carry
    rec = jnp.einsum("bhd,hde->bhe", h_prev, r_blocks)      # block-diagonal R
    pre = wx + rec
    i_r, f_r, z_r, o_r = jnp.split(pre, 4, axis=-1)
    m_new = jnp.maximum(f_r + m, i_r)
    i_ = jnp.exp(i_r - m_new)
    f_ = jnp.exp(f_r + m - m_new)
    c = f_ * c + i_ * jnp.tanh(z_r)
    n = f_ * n + i_
    h = jax.nn.sigmoid(o_r) * c / jnp.maximum(n, 1.0)
    return (c, n, m_new, h), h


def slstm_apply(params: Dict, x: jax.Array, cfg: ModelConfig,
                policy: Policy) -> jax.Array:
    B, S, _ = x.shape
    H = cfg.num_heads
    di = cfg.d_inner
    dh = di // H
    wx = layers.dense_apply(params["w_in"], x, policy).astype(jnp.float32)
    wx = wx.reshape(B, S, H, 4 * dh)
    r = params["r_blocks"].astype(jnp.float32)

    chunk = min(cfg.lstm_chunk, S)
    if S % chunk:
        chunk = S
    nchunks = S // chunk

    def chunk_fn(carry, xc):
        carry, hs = jax.lax.scan(
            lambda c, w: _slstm_step(c, w, r), carry, xc.swapaxes(0, 1))
        return carry, hs.swapaxes(0, 1)

    z = jnp.zeros((B, H, dh), jnp.float32)
    init = (z, z, jnp.zeros((B, H, dh), jnp.float32), z)
    _, hs = _chunk_scan(chunk_fn, init,
                        wx.reshape(B, nchunks, chunk, H, 4 * dh),
                        nchunks, unroll=cfg.force_unroll, remat=cfg.remat)
    h = hs.reshape(B, S, di).astype(x.dtype)
    return layers.dense_apply(params["down_proj"], h, policy)


def slstm_state_init(cfg: ModelConfig, batch: int) -> Dict:
    H = cfg.num_heads
    dh = cfg.d_inner // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"c": z, "n": z, "m": z, "h": z}


def slstm_decode_step(params: Dict, x: jax.Array, state: Dict, cfg: ModelConfig,
                      policy: Policy) -> Tuple[jax.Array, Dict]:
    B = x.shape[0]
    H = cfg.num_heads
    dh = cfg.d_inner // H
    wx = layers.dense_apply(params["w_in"], x, policy).astype(jnp.float32)
    wx = wx.reshape(B, H, 4 * dh)
    carry = (state["c"], state["n"], state["m"], state["h"])
    (c, n, m, h), _ = _slstm_step(carry, wx,
                                  params["r_blocks"].astype(jnp.float32))
    out = layers.dense_apply(params["down_proj"],
                             h.reshape(B, 1, cfg.d_inner).astype(x.dtype),
                             policy)
    return out, {"c": c, "n": n, "m": m, "h": h}
