"""Fused Ozaki-II GEMM Pallas kernel (paper §5.1 discipline, dense-GEMM workload).

TPU mapping of the paper's register-fusion pattern:
  * operands arrive as exact (hi, lo) int32 pairs of the Phase-1 scaled integers —
    8 B/element, identical to native-FP64 HBM traffic (β = 1 for the inputs);
  * per-modulus residue planes are computed in VMEM immediately after the tile load
    (the paper's "in registers" — VREGs after Mosaic vectorisation);
  * one int8 × int8 → int32 MXU contraction per modulus per K-step, accumulated in a
    VMEM scratch (the paper's r accumulator fragments);
  * balanced-digit Garner runs on the accumulators before the single store.

Block shapes default to MXU-friendly multiples (second-minor 8/32, minor 128 lanes);
the VMEM working set is r·bm·bn·4 B of accumulator + (bm+bn)·bk·8 B of tiles —
r=16, bm=bn=128, bk=256: ~1.0 MiB + 0.5 MiB, comfortably inside a v5e core's VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import ozaki2
from repro.kernels import common


def _gemm_kernel(a_hi_ref, a_lo_ref, b_hi_ref, b_lo_ref, out_ref, acc_ref, *,
                 plan: ozaki2.Plan, out_rep: str, k_steps: int):
    kidx = pl.program_id(2)

    @pl.when(kidx == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Residue decomposition of the freshly-loaded tiles — stays in VMEM/VREGs.
    a_res = common.residues_int32(a_hi_ref[...], a_lo_ref[...], plan.moduli)
    b_res = common.residues_int32(b_hi_ref[...], b_lo_ref[...], plan.moduli)

    for i, m in enumerate(plan.moduli):
        part = jax.lax.dot_general(
            a_res[i].astype(jnp.int8), b_res[i].astype(jnp.int8),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
        acc_ref[i] = common.balanced_mod(acc_ref[i] + part, m)

    @pl.when(kidx == k_steps - 1)
    def _epilogue():
        digits = common.garner_digits([acc_ref[i] for i in range(plan.r)], plan)
        if out_rep == "f64":
            out_ref[...] = common.digits_to_f64(digits, plan)
        elif out_rep == "ds":
            hi, lo = common.digits_to_ds(digits, plan)
            out_ref[0] = hi
            out_ref[1] = lo
        else:  # digits
            out_ref[...] = common.stack_digits_int8(digits)


@functools.partial(jax.jit, static_argnames=("plan", "out_rep", "bm", "bn", "bk",
                                             "interpret"))
def gemm_hilo(a_hi: jax.Array, a_lo: jax.Array, b_hi: jax.Array, b_lo: jax.Array,
              plan: ozaki2.Plan, out_rep: str = "f64",
              bm: int = 128, bn: int = 128, bk: int = 256,
              interpret: bool = True) -> jax.Array:
    """Raw kernel entry on pre-scaled (hi, lo) operands.  Shapes must tile evenly.

    Returns: f64 (M,N) | ds f32 (2,M,N) | digits int8 (r,M,N) — the *integer-scaled*
    product; callers apply the exact power-of-two unscale.
    """
    M, K = a_hi.shape
    K2, N = b_hi.shape
    assert K == K2 and M % bm == 0 and N % bn == 0 and K % bk == 0, \
        (a_hi.shape, b_hi.shape, bm, bn, bk)
    k_steps = K // bk
    grid = (M // bm, N // bn, k_steps)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    if out_rep == "f64":
        out_shape = jax.ShapeDtypeStruct((M, N), jnp.float64)
        out_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
    elif out_rep == "ds":
        out_shape = jax.ShapeDtypeStruct((2, M, N), jnp.float32)
        out_spec = pl.BlockSpec((2, bm, bn), lambda i, j, k: (0, i, j))
    elif out_rep == "digits":
        out_shape = jax.ShapeDtypeStruct((plan.r, M, N), jnp.int8)
        out_spec = pl.BlockSpec((plan.r, bm, bn), lambda i, j, k: (0, i, j))
    else:
        raise ValueError(f"out_rep must be one of {common.OUT_REPS}")

    kernel = functools.partial(_gemm_kernel, plan=plan, out_rep=out_rep,
                               k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((plan.r, bm, bn), jnp.int32)],
        interpret=interpret,
    )(a_hi, a_lo, b_hi, b_lo)
