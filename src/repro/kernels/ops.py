"""Jit'd public wrappers for the fused Ozaki-II Pallas kernels.

Two tiers of wrapper live here:

  * ``ozaki_gemm`` / ``ozaki_gemv`` — kernel-level wrappers: the cheap
    streaming pre/post work (Phase-1 scaling, hi/lo split, padding to block
    multiples, digit epilogue, exact unscale) around one ``pallas_call``.
    These ARE the pallas route; ``repro.core.dispatch.matmul`` calls them and
    decides ``interpret`` (Mosaic on TPU, interpreter elsewhere).
  * ``ozaki_spmv_bell`` / ``ozaki_stencil7`` / ``ozaki_attention`` — routed
    entry points: thin delegates to ``dispatch.spmv`` / ``dispatch.stencil7``
    / ``dispatch.attention``, so ``mode_scope`` / ``REPRO_DISPATCH`` flips
    them between the fused kernel and the bit-identical reference like every
    other multiplication in the repo.  Route selection (and the interpret
    flavour of the pallas route) lives in the dispatch layer only.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dispatch, ozaki2, splitting
from repro.kernels import common
from repro.kernels import ozaki_gemm as _gemm
from repro.kernels import ozaki_gemv as _gemv


def _pad2(x: jax.Array, bm: int, bn: int) -> jax.Array:
    M, N = x.shape
    pm, pn = (-M) % bm, (-N) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def _working_f64():
    return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32


def _finish(raw: jax.Array, plan: ozaki2.Plan, out_rep: str,
            shape: Tuple[int, int]) -> jax.Array:
    """Epilogue: raw kernel output -> scaled-integer product as working float."""
    M, N = shape
    if out_rep == "f64":
        return raw[:M, :N]
    if out_rep == "ds":
        return (raw[0].astype(_working_f64())
                + raw[1].astype(_working_f64()))[:M, :N]
    if out_rep == "digits":
        digits = common.unstack_digits(raw)
        return common.digits_to_f64(digits, plan, out_dtype=_working_f64())[:M, :N]
    raise ValueError(out_rep)


def ozaki_gemm(a: jax.Array, b: jax.Array, plan: Optional[ozaki2.Plan] = None,
               out_rep: str = "f64", bm: int = 128, bn: int = 128, bk: int = 256,
               interpret: Optional[bool] = None) -> jax.Array:
    """FP64-accurate C = A @ B through the fused Pallas kernel."""
    M, K = a.shape
    _, N = b.shape
    if plan is None:
        plan = dispatch.get_plan(K)
    if interpret is None:
        interpret = dispatch.pallas_interpret("gemm")
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    f64 = _working_f64()

    ai, sa = splitting.scale_to_int(a.astype(f64), plan.payload_bits, axis=-1)
    bi, sb = splitting.scale_to_int(b.astype(f64), plan.payload_bits, axis=0)
    a_hi, a_lo = splitting.split_hi_lo(ai)
    b_hi, b_lo = splitting.split_hi_lo(bi)
    a_hi, a_lo = _pad2(a_hi, bm, bk), _pad2(a_lo, bm, bk)
    b_hi, b_lo = _pad2(b_hi, bk, bn), _pad2(b_lo, bk, bn)

    raw = _gemm.gemm_hilo(a_hi, a_lo, b_hi, b_lo, plan, out_rep=out_rep,
                          bm=bm, bn=bn, bk=bk, interpret=interpret)
    c = _finish(raw, plan, out_rep, (M, N))
    return splitting.apply_unscale(c, sa, sb)


def ozaki_gemv(a: jax.Array, x: jax.Array, plan: Optional[ozaki2.Plan] = None,
               out_rep: str = "f64", bm: int = 128, bk: int = 256,
               interpret: Optional[bool] = None) -> jax.Array:
    """Batched GEMV Y = A @ X (paper Alg. 1): A (M,N) fp64, X (N,B) with small B."""
    M, N = a.shape
    _, B = x.shape
    if plan is None:
        plan = dispatch.get_plan(N)
    if interpret is None:
        interpret = dispatch.pallas_interpret("gemv")
    bm, bk = min(bm, M), min(bk, N)
    f64 = _working_f64()

    ai, sa = splitting.scale_to_int(a.astype(f64), plan.payload_bits, axis=-1)
    xi, sx = splitting.scale_to_int(x.astype(f64), plan.payload_bits, axis=0)
    a_hi, a_lo = splitting.split_hi_lo(ai)
    x_hi, x_lo = splitting.split_hi_lo(xi)
    a_hi, a_lo = _pad2(a_hi, bm, bk), _pad2(a_lo, bm, bk)
    x_hi, x_lo = _pad2(x_hi, bk, B), _pad2(x_lo, bk, B)

    raw = _gemv.gemv_hilo(a_hi, a_lo, x_hi, x_lo, plan, out_rep=out_rep,
                          bm=bm, bk=bk, interpret=interpret)
    y = _finish(raw, plan, out_rep, (M, B))
    return splitting.apply_unscale(y, sa, sx)


def ozaki_stencil7(u: jax.Array, c: jax.Array,
                   plan: Optional[ozaki2.Plan] = None, out_rep: str = "f64",
                   bz: int = 8, mode: Optional[str] = None) -> jax.Array:
    """7-point 3-D stencil (paper Alg. 2) at FP64 accuracy, dispatch-routed.

    u: (X, Y, Z) grid, c: (7,) coefficients ordered
    [centre, -x, +x, -y, +y, -z, +z].  Boundary points use zero halo.
    ``mode`` (or the ambient ``mode_scope`` / ``REPRO_DISPATCH``) selects the
    fused Pallas kernel or the bit-identical jnp reference.
    """
    return dispatch.stencil7(u, c, plan=plan, out_rep=out_rep, bz=bz, mode=mode)


def ozaki_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    mask: Optional[jax.Array] = None, softcap: float = 0.0,
                    plan_qk: Optional[ozaki2.Plan] = None,
                    plan_pv: Optional[ozaki2.Plan] = None,
                    mode: Optional[str] = None) -> jax.Array:
    """Fused emulated attention softmax(mask(QKᵀ/√D)) V, dispatch-routed.

    q: (..., S, D), k/v: (..., T, D), mask: None | (S, T) | (..., S, T)
    (nonzero = attend).  ``mode`` selects the FlashAttention-style fused
    Pallas kernel (QKᵀ and PV ride the Ozaki-II residue pipeline inside one
    online-softmax scan) or the bit-identical reference composed from the
    seam GEMMs, like every dispatch-seam multiplication.
    """
    return dispatch.attention(q, k, v, mask=mask, softcap=softcap,
                              plan_qk=plan_qk, plan_pv=plan_pv, mode=mode)


def ozaki_spmv_bell(a_val: jax.Array, a_col: jax.Array, x: jax.Array,
                    plan: Optional[ozaki2.Plan] = None, out_rep: str = "f64",
                    br: int = 128, mode: Optional[str] = None) -> jax.Array:
    """Blocked-ELL SpMV y = A x (paper Alg. 3), dispatch-routed.

    a_val: (M, bw) padded per-row nonzero values; a_col: (M, bw) int32 column
    indices (structural-zero slots must point at a valid column, value 0.0).

    ``mode`` selects the route like every dispatch-seam multiplication.  On
    CPU backends ``auto`` takes the bit-identical jnp reference: the
    interpreted ``pallas_call`` hands XLA a gather-heavy graph with a
    multi-minute compile — a correctness oracle (``mode="pallas"``, used by
    the slow-lane parity test), not a path anyone should pay by default.  On
    TPU ``auto`` is the fused Mosaic kernel.
    """
    return dispatch.spmv(a_val, a_col, x, plan=plan, out_rep=out_rep, br=br,
                         mode=mode)
