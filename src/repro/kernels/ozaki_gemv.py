"""Fused Ozaki-II batched GEMV Pallas kernel (paper §5.2, Algorithm 1).

Y = A·X with A (M, N) and a small batch X (N, B).  B maps onto the MXU minor
dimension (the paper's 16/32-wide tensor-core n-dim); the M and N axes tile.
Operational intensity ≈ B/2 FLOPs/B, the regime where the TME model predicts the
largest memory-bound win on FP64-starved parts (~24x on B300 at B=8).

The fusion discipline is identical to ozaki_gemm: (hi, lo) int32 operands in,
residues and accumulators VMEM-resident, Garner before store.  Register-pressure
note from §5.2: r accumulator planes of (bm, B) int32 — at r=16, bm=128, B=8 that
is 64 KiB of VMEM scratch, far below the spill threshold; the paper's caveat that
B ≳ 8 forces spilling applies to CUDA register files, not to VMEM-scale scratch
(an honest TPU-vs-GPU difference recorded in DESIGN.md §3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import ozaki2
from repro.kernels import common


def _gemv_kernel(a_hi_ref, a_lo_ref, x_hi_ref, x_lo_ref, out_ref, acc_ref, *,
                 plan: ozaki2.Plan, out_rep: str, k_steps: int):
    kidx = pl.program_id(1)

    @pl.when(kidx == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a_res = common.residues_int32(a_hi_ref[...], a_lo_ref[...], plan.moduli)
    x_res = common.residues_int32(x_hi_ref[...], x_lo_ref[...], plan.moduli)

    for i, m in enumerate(plan.moduli):
        part = jax.lax.dot_general(
            a_res[i].astype(jnp.int8), x_res[i].astype(jnp.int8),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
        acc_ref[i] = common.balanced_mod(acc_ref[i] + part, m)

    @pl.when(kidx == k_steps - 1)
    def _epilogue():
        digits = common.garner_digits([acc_ref[i] for i in range(plan.r)], plan)
        if out_rep == "f64":
            out_ref[...] = common.digits_to_f64(digits, plan)
        elif out_rep == "ds":
            hi, lo = common.digits_to_ds(digits, plan)
            out_ref[0] = hi
            out_ref[1] = lo
        else:
            out_ref[...] = common.stack_digits_int8(digits)


@functools.partial(jax.jit, static_argnames=("plan", "out_rep", "bm", "bk",
                                             "interpret"))
def gemv_hilo(a_hi: jax.Array, a_lo: jax.Array, x_hi: jax.Array, x_lo: jax.Array,
              plan: ozaki2.Plan, out_rep: str = "f64", bm: int = 128,
              bk: int = 256, interpret: bool = True) -> jax.Array:
    M, N = a_hi.shape
    _, B = x_hi.shape
    assert M % bm == 0 and N % bk == 0
    k_steps = N // bk
    grid = (M // bm, k_steps)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
        pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
        pl.BlockSpec((bk, B), lambda i, k: (k, 0)),
        pl.BlockSpec((bk, B), lambda i, k: (k, 0)),
    ]
    if out_rep == "f64":
        out_shape = jax.ShapeDtypeStruct((M, B), jnp.float64)
        out_spec = pl.BlockSpec((bm, B), lambda i, k: (i, 0))
    elif out_rep == "ds":
        out_shape = jax.ShapeDtypeStruct((2, M, B), jnp.float32)
        out_spec = pl.BlockSpec((2, bm, B), lambda i, k: (0, i, 0))
    elif out_rep == "digits":
        out_shape = jax.ShapeDtypeStruct((plan.r, M, B), jnp.int8)
        out_spec = pl.BlockSpec((plan.r, bm, B), lambda i, k: (0, i, 0))
    else:
        raise ValueError(f"out_rep must be one of {common.OUT_REPS}")

    kernel = functools.partial(_gemv_kernel, plan=plan, out_rep=out_rep,
                               k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((plan.r, bm, B), jnp.int32)],
        interpret=interpret,
    )(a_hi, a_lo, x_hi, x_lo)
