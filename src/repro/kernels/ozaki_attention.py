"""Fused Ozaki-II attention Pallas kernel (FlashAttention scan over slice GEMMs).

Attention is the layer-3 dwarf the serving stack actually spends its time in,
and the shape the paper's register-fusion argument (§5.1) is sharpest about:
the (S, T) score and probability matrices are pure intermediates, so a fused
online-softmax scan that keeps them resident in VMEM turns the whole op
memory-bound in q/k/v/out alone (β = 1), while the unfused composition of seam
GEMMs must round-trip r residue planes *and* the materialised S/P matrices
through HBM.

TPU mapping of the fused scan:
  * q and k arrive pre-scaled per row over the head dimension, v per
    (kv-block, column) over the block — exactly the scaling granularity the
    per-block reference GEMMs use, which is what makes the two routes
    bit-identical;
  * each grid step loads one (bq, D) q-tile against one (bkv, D) k/v-tile,
    computes QKᵀ through the int8 residue pipeline (residues in VMEM, one
    int8×int8→int32 MXU contraction per modulus, balanced-digit Garner),
    applies scale/softcap/mask, folds the block into the running
    (m, l, acc) online-softmax state, and feeds the block's probabilities
    straight back through a second residue pipeline for PV;
  * the only stores are the final acc / l — no S/P matrix ever exists at
    full size.

Bit-identity contract (the dispatch seam's invariant, verified by
tests/test_attention.py): ``attention_ref`` composes ``ozaki2.emulated_matmul``
per kv-block with the *same* block size, padding, scaling axes, and shared
``_masked_scores``/``_online_update`` helpers, so both routes perform the same
float operations in the same order on the same exact integer products.  The
in-kernel f64 epilogue is valid in interpret mode (this container) and on
backends with f64 vector support; a digits/ds output variant for compiled
Mosaic is the accelerator-lane follow-on, as for the GEMM kernel.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import ozaki2, splitting
from repro.kernels import common

# Finite stand-in for -inf (matches repro.models.attention.NEG_INF): keeps the
# online-softmax state NaN-free for fully-masked rows on both routes.
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Shared per-block math — textually the same code on both routes
# ---------------------------------------------------------------------------

def _masked_scores(s_prod: jax.Array, mask_blk: jax.Array, softcap: float,
                   inv_sqrt_d: float) -> jax.Array:
    """Scale / softcap / mask one block of raw QKᵀ products.

    Op order matches the models' score path: scores·(1/√D), then the tanh
    softcap (when enabled), then masked positions to NEG_INF.
    """
    s = s_prod * inv_sqrt_d
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    return jnp.where(mask_blk, s, NEG_INF)


def _online_update(s: jax.Array, m: jax.Array, l: jax.Array):
    """One FlashAttention online-softmax step over a (rows, bkv) score block.

    Returns (p, corr, m_new, l_new): the block's unnormalised probabilities,
    the correction factor for the running accumulator, and the updated
    running max / normaliser.
    """
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    corr = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l * corr + jnp.sum(p, axis=-1)
    return p, corr, m_new, l_new


# ---------------------------------------------------------------------------
# XLA reference: the same scan composed from the seam GEMMs
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("plan_qk", "plan_pv", "softcap",
                                             "bkv", "out_dtype"))
def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
                  plan_qk: ozaki2.Plan, plan_pv: ozaki2.Plan,
                  softcap: float = 0.0, bkv: int = 128,
                  out_dtype=jnp.float64) -> jax.Array:
    """Bit-identical reference for ``attention_fused`` built from seam GEMMs.

    q: (S, D), k/v: (T, D), mask: (S, T) (int8 or bool; nonzero = attend).
    Scans kv-blocks of ``bkv`` rows in kernel order, computing each block's
    QKᵀ and PV products with ``ozaki2.emulated_matmul`` — the same exact
    integer products and reconstruction the fused kernel performs in VMEM,
    at the same scaling granularity (q/k per row over D; p per row and v per
    column over the block).  Bit-identical to the fused kernel for any
    (bq, bkv) blocking, the same way ``stencil7_ref`` is for z-blocking.
    """
    S, D = q.shape
    T = k.shape[0]
    q = q.astype(out_dtype)
    tp = -(-T // bkv) * bkv
    kp = jnp.pad(k.astype(out_dtype), ((0, tp - T), (0, 0)))
    vp = jnp.pad(v.astype(out_dtype), ((0, tp - T), (0, 0)))
    mp = jnp.pad(mask.astype(jnp.int8), ((0, 0), (0, tp - T)))
    kb = kp.reshape(tp // bkv, bkv, D)
    vb = vp.reshape(tp // bkv, bkv, D)
    mb = jnp.moveaxis(mp.reshape(S, tp // bkv, bkv), 1, 0)
    inv_sqrt_d = 1.0 / math.sqrt(D)

    def step(carry, blk):
        m, l, acc = carry
        k_blk, v_blk, mask_blk = blk
        s_prod = ozaki2.emulated_matmul(q, k_blk.T, plan_qk,
                                        out_dtype=out_dtype)
        s = _masked_scores(s_prod, mask_blk != 0, softcap, inv_sqrt_d)
        p, corr, m, l = _online_update(s, m, l)
        pv = ozaki2.emulated_matmul(p, v_blk, plan_pv, out_dtype=out_dtype)
        acc = acc * corr[:, None] + pv
        return (m, l, acc), None

    init = (jnp.full((S,), NEG_INF, out_dtype), jnp.zeros((S,), out_dtype),
            jnp.zeros((S, D), out_dtype))
    (m, l, acc), _ = jax.lax.scan(step, init, (kb, vb, mb))
    return acc / l[:, None]


# ---------------------------------------------------------------------------
# Fused Pallas kernel
# ---------------------------------------------------------------------------

def _attn_kernel(q_hi_ref, q_lo_ref, sq_ref, k_hi_ref, k_lo_ref, sk_ref,
                 v_hi_ref, v_lo_ref, sv_ref, mask_ref, out_ref,
                 m_ref, l_ref, acc_ref, *, plan_qk: ozaki2.Plan,
                 plan_pv: ozaki2.Plan, softcap: float, inv_sqrt_d: float,
                 kv_steps: int, out_dtype):
    jidx = pl.program_id(1)

    @pl.when(jidx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # QK^T slice product: residues of the freshly-loaded (hi, lo) tiles stay
    # in VMEM/VREGs; one int8 MXU contraction per modulus; Garner before use.
    q_res = common.residues_int32(q_hi_ref[...], q_lo_ref[...], plan_qk.moduli)
    k_res = common.residues_int32(k_hi_ref[...], k_lo_ref[...], plan_qk.moduli)
    accs = []
    for i, mod in enumerate(plan_qk.moduli):
        part = jax.lax.dot_general(
            q_res[i].astype(jnp.int8), k_res[i].astype(jnp.int8),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32)
        accs.append(common.balanced_mod(part, mod))
    digits = common.garner_digits(accs, plan_qk)
    s_int = common.digits_to_f64(digits, plan_qk, out_dtype=out_dtype)
    s_prod = splitting.apply_unscale(s_int, sq_ref[...][:, 0], sk_ref[...][:, 0])

    s = _masked_scores(s_prod, mask_ref[...] != 0, softcap, inv_sqrt_d)
    p, corr, m_new, l_new = _online_update(s, m_ref[...][:, 0], l_ref[...][:, 0])
    m_ref[...] = m_new[:, None]
    l_ref[...] = l_new[:, None]

    # PV slice product: the block's probabilities decompose in-kernel (Phase-1
    # scaling per row over bkv — the reference GEMM's granularity) and ride a
    # second residue pipeline against the pre-scaled v tile.
    pi, sp = splitting.scale_to_int(p, plan_pv.payload_bits, axis=-1)
    p_hi, p_lo = splitting.split_hi_lo(pi)
    p_res = common.residues_int32(p_hi, p_lo, plan_pv.moduli)
    v_res = common.residues_int32(v_hi_ref[...], v_lo_ref[...], plan_pv.moduli)
    accs = []
    for i, mod in enumerate(plan_pv.moduli):
        part = jax.lax.dot_general(
            p_res[i].astype(jnp.int8), v_res[i].astype(jnp.int8),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
        accs.append(common.balanced_mod(part, mod))
    digits = common.garner_digits(accs, plan_pv)
    pv_int = common.digits_to_f64(digits, plan_pv, out_dtype=out_dtype)
    pv = splitting.apply_unscale(pv_int, sp, sv_ref[...][0])

    acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(jidx == kv_steps - 1)
    def _epilogue():
        out_ref[...] = acc_ref[...] / l_ref[...]


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[0]) % mult
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1)) if pad else x


@functools.partial(jax.jit, static_argnames=("plan_qk", "plan_pv", "softcap",
                                             "bq", "bkv", "interpret",
                                             "out_dtype"))
def attention_fused(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
                    plan_qk: ozaki2.Plan, plan_pv: ozaki2.Plan,
                    softcap: float = 0.0, bq: int = 128, bkv: int = 128,
                    interpret: bool = True, out_dtype=jnp.float64) -> jax.Array:
    """Fused emulated attention: out = softmax(mask(QKᵀ/√D)) V in one scan.

    q: (S, D), k/v: (T, D), mask: (S, T) (nonzero = attend).  Grid is
    (S/bq, T/bkv) with the kv axis innermost; the (m, l, acc) online-softmax
    state lives in VMEM scratch across the kv sweep.  Zero-padding of S, T,
    and D to block multiples is exact (padded rows/cols scale with shift 0 and
    contribute zero residues; padded key columns are masked), so the unpadded
    region is bit-identical to ``attention_ref`` at the same ``bkv``.
    """
    S, D = q.shape
    T = k.shape[0]
    inv_sqrt_d = 1.0 / math.sqrt(D)
    dp = -(-D // 128) * 128
    tp = -(-T // bkv) * bkv
    sp_ = -(-S // bq) * bq
    kv_steps = tp // bkv

    # Phase-1 scaling at the reference GEMMs' granularity, *before* padding
    # (rows/blocks are whole either way, so the shifts are identical).
    qi, sq = splitting.scale_to_int(q.astype(out_dtype),
                                    plan_qk.payload_bits, axis=-1)
    ki, sk = splitting.scale_to_int(k.astype(out_dtype),
                                    plan_qk.payload_bits, axis=-1)
    vp = _pad_rows(v.astype(out_dtype), bkv).reshape(kv_steps, bkv, D)
    vi, sv = splitting.scale_to_int(vp, plan_pv.payload_bits, axis=1)

    def hilo(xi, rows, cols):
        hi, lo = splitting.split_hi_lo(xi)
        padder = lambda a: jnp.pad(a, ((0, rows - a.shape[0]),
                                       (0, cols - a.shape[1])))
        return padder(hi), padder(lo)

    q_hi, q_lo = hilo(qi, sp_, dp)
    k_hi, k_lo = hilo(ki, tp, dp)
    v_hi, v_lo = hilo(vi.reshape(tp, D), tp, dp)
    sq_p = jnp.pad(sq, (0, sp_ - S)).reshape(sp_, 1)
    sk_p = jnp.pad(sk, (0, tp - T)).reshape(tp, 1)
    sv_p = jnp.pad(sv, ((0, 0), (0, dp - D)))
    mask_p = jnp.pad(mask.astype(jnp.int8), ((0, sp_ - S), (0, tp - T)))

    grid = (sp_ // bq, kv_steps)
    in_specs = [
        pl.BlockSpec((bq, dp), lambda i, j: (i, 0)),      # q_hi
        pl.BlockSpec((bq, dp), lambda i, j: (i, 0)),      # q_lo
        pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),       # sq
        pl.BlockSpec((bkv, dp), lambda i, j: (j, 0)),     # k_hi
        pl.BlockSpec((bkv, dp), lambda i, j: (j, 0)),     # k_lo
        pl.BlockSpec((bkv, 1), lambda i, j: (j, 0)),      # sk
        pl.BlockSpec((bkv, dp), lambda i, j: (j, 0)),     # v_hi
        pl.BlockSpec((bkv, dp), lambda i, j: (j, 0)),     # v_lo
        pl.BlockSpec((1, dp), lambda i, j: (j, 0)),       # sv
        pl.BlockSpec((bq, bkv), lambda i, j: (i, j)),     # mask
    ]
    kernel = functools.partial(_attn_kernel, plan_qk=plan_qk, plan_pv=plan_pv,
                               softcap=softcap, inv_sqrt_d=inv_sqrt_d,
                               kv_steps=kv_steps, out_dtype=out_dtype)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bq, dp), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sp_, dp), out_dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), out_dtype),
                        pltpu.VMEM((bq, 1), out_dtype),
                        pltpu.VMEM((bq, dp), out_dtype)],
        interpret=interpret,
    )(q_hi, q_lo, sq_p, k_hi, k_lo, sk_p, v_hi, v_lo, sv_p, mask_p)
    return out[:S, :D]
