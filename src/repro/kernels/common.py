"""Shared in-kernel primitives for the fused Ozaki-II Pallas kernels.

The β→1 discipline (paper §5.1) in TPU terms: operands enter the kernel as (hi, lo)
int32 pairs (8 B/elem — the same HBM traffic as native FP64); residue planes are
computed *inside* the kernel in VMEM/VREGs and never round-trip to HBM; the Garner
reconstruction runs on the int32 accumulators before the store.

Output representations (the one place the TPU adaptation pays a real cost, since
Mosaic has no float64 type):
  f64    — full in-kernel double-double Garner.  Bit-equivalent to the XLA reference;
           valid in interpret mode (this container) and on backends with f64.
  digits — TPU-production mode: the kernel stores the r balanced mixed-radix digits
           as int8 (r bytes/output vs 8 for f64) and a cheap bandwidth-bound XLA
           epilogue finishes the double-double Horner.  β_out = r/8.
  ds     — two-float32 double-single output (8 B/output, β_out = 1) with ~49-bit
           accuracy: full-bandwidth mode for consumers that tolerate 2^-45 error.

All helpers are shape-polymorphic jnp code so they trace identically inside
pl.pallas_call (interpret or Mosaic) and in the XLA reference path.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ozaki2
from repro.core.moduli import SPLIT_RADIX

OUT_REPS = ("f64", "digits", "ds")


def balanced_mod(v: jax.Array, m: int) -> jax.Array:
    u = jnp.remainder(v, m)
    return jnp.where(u > (m - 1) // 2, u - m, u)


def residues_int32(hi: jax.Array, lo: jax.Array, moduli: Sequence[int]) -> List[jax.Array]:
    """Balanced residues of x = hi*2^26 + lo per modulus; int32-only arithmetic."""
    outs = []
    for m in moduli:
        v = balanced_mod(hi, m) * (SPLIT_RADIX % m) + balanced_mod(lo, m)
        outs.append(balanced_mod(v, m))
    return outs


def garner_digits(accs: Sequence[jax.Array], plan: ozaki2.Plan) -> List[jax.Array]:
    """Balanced mixed-radix digits v_j (int32 arrays) from per-modulus accumulators."""
    gc = plan.garner
    ms = plan.moduli
    r = plan.r
    carry = [jnp.zeros_like(accs[0]) for _ in range(r)]
    digits: List[jax.Array] = []
    for j in range(r):
        t = balanced_mod((balanced_mod(accs[j], ms[j]) - carry[j])
                         * int(gc.inv_pref[j]), ms[j])
        digits.append(t)
        for l in range(j + 1, r):
            carry[l] = balanced_mod(carry[l] + t * int(gc.pref_mod[j, l]), ms[l])
    return digits


def digits_to_f64(digits: Sequence[jax.Array], plan: ozaki2.Plan,
                  out_dtype=jnp.float64) -> jax.Array:
    """Compensated double-double Horner over the digits (the reconstruction epilogue)."""
    gc = plan.garner
    out = jnp.zeros(digits[0].shape, out_dtype)
    comp = jnp.zeros(digits[0].shape, out_dtype)
    split_bits = 27 if out_dtype == jnp.float64 else 12
    split_c = (2.0 ** split_bits + 1.0)
    for j, t in enumerate(digits):
        tf = t.astype(out_dtype)
        ph = jnp.asarray(gc.pref_f64[j], out_dtype)
        p = tf * ph
        # two_prod(tf, ph) inline (Veltkamp)
        c1 = split_c * tf
        tf_h = c1 - (c1 - tf)
        tf_l = tf - tf_h
        c2 = split_c * ph
        ph_h = c2 - (c2 - ph)
        ph_l = ph - ph_h
        e = ((tf_h * ph_h - p) + tf_h * ph_l + tf_l * ph_h) + tf_l * ph_l
        e = e + tf * jnp.asarray(gc.pref_f64_lo[j], out_dtype)
        # two_sum(out, p)
        s = out + p
        v = s - out
        comp = comp + ((out - (s - v)) + (p - v)) + e
        out = s
    return out + comp


def digits_to_ds(digits: Sequence[jax.Array], plan: ozaki2.Plan
                 ) -> Tuple[jax.Array, jax.Array]:
    """Double-single (f32, f32) reconstruction — the β_out = 1 TPU fast path.

    Full double-single arithmetic: each prefix product is carried as an exact
    (hi, lo) f32 pair and each digit term uses a Veltkamp two_prod, so the result
    holds ~45-48 significant bits (vs 24 for a naive f32 Horner).
    """
    gc = plan.garner
    split_c = jnp.float32(2.0 ** 12 + 1.0)
    hi = jnp.zeros(digits[0].shape, jnp.float32)
    lo = jnp.zeros(digits[0].shape, jnp.float32)
    for j, t in enumerate(digits):
        tf = t.astype(jnp.float32)
        ph_np = np.float32(gc.pref_f64[j])
        ph = jnp.asarray(ph_np)
        pl_ = jnp.asarray(np.float32(gc.pref_f64[j] - np.float64(ph_np)))
        # two_prod(tf, ph) in f32
        p = tf * ph
        c1 = split_c * tf
        tf_h = c1 - (c1 - tf)
        tf_l = tf - tf_h
        c2 = split_c * ph
        ph_h = c2 - (c2 - ph)
        ph_l = ph - ph_h
        e = ((tf_h * ph_h - p) + tf_h * ph_l + tf_l * ph_h) + tf_l * ph_l
        e = e + tf * pl_
        # two_sum(hi, p)
        s = hi + p
        v = s - hi
        lo = lo + ((hi - (s - v)) + (p - v)) + e
        hi = s
    s = hi + lo
    lo = lo - (s - hi)
    return s, lo


def stack_digits_int8(digits: Sequence[jax.Array]) -> jax.Array:
    return jnp.stack([d.astype(jnp.int8) for d in digits], axis=0)


def unstack_digits(d8: jax.Array) -> List[jax.Array]:
    return [d8[j].astype(jnp.int32) for j in range(d8.shape[0])]
