"""Fused Ozaki-II 7-point stencil Pallas kernel (paper §5.3, Algorithm 2).

im2col-in-registers mapping: per z-slab, the 7-point neighbourhood of every output
is assembled in VMEM, residue-decomposed, and contracted against the pre-decomposed
coefficient residues (the paper's constant-memory table — here a tiny (r, 7) int8
operand) with a 1×7×N_tile int8 MXU contraction per modulus.

Halo handling without β inflation: the z-axis is blocked and each program receives
the *previous*, *current* and *next* slabs of the same array through three
BlockSpecs with clamped index maps — the TPU equivalent of a halo'd shared-memory
tile (re-reads hit the same HBM pages the neighbouring programs stream anyway; the
paper's §5.3 traffic model already counts them as cached).  Global-boundary planes
are masked to the zero halo inside the kernel.

HBM traffic per output: 8 B in (hi+lo int32) + 8 B out (f64 mode) — exactly the
native-FP64 footprint, β = 1 (out_rep="digits" pays r/8 instead, see common.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import ozaki2, splitting
from repro.kernels import common


def _global_scale_to_int(x: jax.Array, payload_bits: int):
    absmax = jnp.max(jnp.abs(x))
    e = jnp.floor(jnp.log2(jnp.where(absmax > 0, absmax, 1.0)))
    shift = (payload_bits - 1) - e.astype(jnp.int32)
    scaled = jnp.ldexp(x, jnp.broadcast_to(shift, x.shape))
    too_big = jnp.max(jnp.abs(scaled)) >= 2.0 ** payload_bits
    shift = shift - too_big.astype(jnp.int32)
    scaled = jnp.where(too_big, scaled * 0.5, scaled)
    return jnp.round(scaled), shift


def _roll_mask(arr: jax.Array, ax: int, d: int) -> jax.Array:
    """Shift by one along ``ax`` with a zero fill at the exposed boundary."""
    rolled = jnp.roll(arr, d, axis=ax)
    idx = [slice(None)] * 3
    idx[ax] = 0 if d == 1 else -1
    return rolled.at[tuple(idx)].set(0)


def _stencil_kernel(c_res_ref, u_hi_p, u_lo_p, u_hi_c, u_lo_c, u_hi_n, u_lo_n,
                    out_ref, *, plan: ozaki2.Plan, out_rep: str, z_steps: int):
    zidx = pl.program_id(0)
    X, Y, bz = u_hi_c.shape

    def neighborhood(cur, prev, nxt):
        """Stack the 7-point neighbourhood: [centre, -x, +x, -y, +y, -z, +z]."""
        zm = jnp.concatenate([prev[:, :, -1:], cur[:, :, :-1]], axis=2)
        zm = jnp.where(zidx == 0,
                       zm.at[:, :, 0].set(0), zm)  # global -z boundary
        zp = jnp.concatenate([cur[:, :, 1:], nxt[:, :, :1]], axis=2)
        zp = jnp.where(zidx == z_steps - 1,
                       zp.at[:, :, -1].set(0), zp)  # global +z boundary
        return jnp.stack([
            cur,
            _roll_mask(cur, 0, 1), _roll_mask(cur, 0, -1),
            _roll_mask(cur, 1, 1), _roll_mask(cur, 1, -1),
            zm, zp,
        ], axis=0)  # (7, X, Y, bz)

    nb_hi = neighborhood(u_hi_c[...], u_hi_p[...], u_hi_n[...])
    nb_lo = neighborhood(u_lo_c[...], u_lo_p[...], u_lo_n[...])

    # im2col: (7, X*Y*bz) residue planes contracted against (1, 7) coefficients.
    nb_hi2 = nb_hi.reshape(7, -1)
    nb_lo2 = nb_lo.reshape(7, -1)
    u_res = common.residues_int32(nb_hi2, nb_lo2, plan.moduli)

    accs = []
    for i, m in enumerate(plan.moduli):
        ci = c_res_ref[i].reshape(1, 7)  # constant-memory analogue
        part = jax.lax.dot_general(
            ci.astype(jnp.int8), u_res[i].astype(jnp.int8),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
        accs.append(common.balanced_mod(part.reshape(X, Y, bz), m))

    digits = common.garner_digits(accs, plan)
    if out_rep == "f64":
        out_ref[...] = common.digits_to_f64(digits, plan)
    elif out_rep == "ds":
        hi, lo = common.digits_to_ds(digits, plan)
        out_ref[0] = hi
        out_ref[1] = lo
    else:
        out_ref[...] = common.stack_digits_int8(digits)


@functools.partial(jax.jit, static_argnames=("plan", "out_rep", "bz", "interpret"))
def stencil7(u: jax.Array, c: jax.Array, plan: ozaki2.Plan,
             out_rep: str = "f64", bz: int = 8, interpret: bool = True) -> jax.Array:
    X, Y, Z = u.shape
    f64 = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    bz = min(bz, Z)
    pz = (-Z) % bz
    ui, su = _global_scale_to_int(u.astype(f64), plan.payload_bits)
    ci, sc = _global_scale_to_int(c.astype(f64), plan.payload_bits)
    u_hi, u_lo = splitting.split_hi_lo(ui)
    if pz:
        u_hi = jnp.pad(u_hi, ((0, 0), (0, 0), (0, pz)))
        u_lo = jnp.pad(u_lo, ((0, 0), (0, 0), (0, pz)))
    c_hi, c_lo = splitting.split_hi_lo(ci)
    c_res = jnp.stack(common.residues_int32(c_hi, c_lo, plan.moduli)).astype(jnp.int8)

    Zp = Z + pz
    z_steps = Zp // bz
    grid = (z_steps,)

    def spec(offset):
        # clamped halo slabs: offset -1 (prev), 0 (cur), +1 (next)
        if offset == -1:
            return pl.BlockSpec((X, Y, bz),
                                lambda k: (0, 0, jnp.maximum(k - 1, 0)))
        if offset == 1:
            return pl.BlockSpec((X, Y, bz),
                                lambda k: (0, 0, jnp.minimum(k + 1, z_steps - 1)))
        return pl.BlockSpec((X, Y, bz), lambda k: (0, 0, k))

    in_specs = [pl.BlockSpec((plan.r, 7), lambda k: (0, 0)),
                spec(-1), spec(-1), spec(0), spec(0), spec(1), spec(1)]

    if out_rep == "f64":
        out_shape = jax.ShapeDtypeStruct((X, Y, Zp), jnp.float64)
        out_spec = pl.BlockSpec((X, Y, bz), lambda k: (0, 0, k))
    elif out_rep == "ds":
        out_shape = jax.ShapeDtypeStruct((2, X, Y, Zp), jnp.float32)
        out_spec = pl.BlockSpec((2, X, Y, bz), lambda k: (0, 0, 0, k))
    elif out_rep == "digits":
        out_shape = jax.ShapeDtypeStruct((plan.r, X, Y, Zp), jnp.int8)
        out_spec = pl.BlockSpec((plan.r, X, Y, bz), lambda k: (0, 0, 0, k))
    else:
        raise ValueError(f"out_rep must be one of {common.OUT_REPS}")

    kernel = functools.partial(_stencil_kernel, plan=plan, out_rep=out_rep,
                               z_steps=z_steps)
    raw = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(c_res, u_hi, u_lo, u_hi, u_lo, u_hi, u_lo)

    if out_rep == "f64":
        v = raw[:, :, :Z]
    elif out_rep == "ds":
        v = (raw[0].astype(f64) + raw[1].astype(f64))[:, :, :Z]
    else:
        v = common.digits_to_f64(common.unstack_digits(raw), plan,
                                 out_dtype=f64)[:, :, :Z]
    return jnp.ldexp(v, jnp.broadcast_to(-(su + sc), v.shape))


@functools.partial(jax.jit, static_argnames=("plan", "out_rep"))
def stencil7_ref(u: jax.Array, c: jax.Array, plan: ozaki2.Plan,
                 out_rep: str = "f64") -> jax.Array:
    """Unfused jnp reference of the fused stencil kernel, bit-identical.

    Same Phase-1 global scaling, hi/lo split, zero-halo neighbourhood
    (``_roll_mask`` is shared with the kernel), residues, per-modulus 7-term
    contraction, Garner digits, and reconstruction epilogue as ``stencil7`` —
    every integer step is exact and point-local, so the result matches the
    Pallas path bit-for-bit regardless of z-blocking.  This is the ``xla``
    route of ``repro.core.dispatch.stencil7``.
    """
    f64 = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    ui, su = _global_scale_to_int(u.astype(f64), plan.payload_bits)
    ci, sc = _global_scale_to_int(c.astype(f64), plan.payload_bits)
    u_hi, u_lo = splitting.split_hi_lo(ui)
    c_hi, c_lo = splitting.split_hi_lo(ci)
    c_res = common.residues_int32(c_hi, c_lo, plan.moduli)

    def neighborhood(arr):
        # Global-array version of the kernel's halo'd stack: the z neighbours
        # come from jnp.roll with the same boundary masking the kernel applies
        # to its first/last slab.
        return jnp.stack([
            arr,
            _roll_mask(arr, 0, 1), _roll_mask(arr, 0, -1),
            _roll_mask(arr, 1, 1), _roll_mask(arr, 1, -1),
            _roll_mask(arr, 2, 1), _roll_mask(arr, 2, -1),
        ], axis=0)  # (7, X, Y, Z)

    nb_hi = neighborhood(u_hi).reshape(7, -1)
    nb_lo = neighborhood(u_lo).reshape(7, -1)
    u_res = common.residues_int32(nb_hi, nb_lo, plan.moduli)

    accs = []
    for i, m in enumerate(plan.moduli):
        # (1, 7) x (7, npts) int32 contraction: |sum| <= 7 * 128 * 128, exact.
        part = jnp.tensordot(c_res[i].reshape(1, 7), u_res[i], axes=(1, 0))
        accs.append(common.balanced_mod(part.reshape(u.shape), m))

    digits = common.garner_digits(accs, plan)
    if out_rep in ("f64", "digits"):
        v = common.digits_to_f64(digits, plan, out_dtype=f64)
    elif out_rep == "ds":
        hi, lo = common.digits_to_ds(digits, plan)
        v = hi.astype(f64) + lo.astype(f64)
    else:
        raise ValueError(f"out_rep must be one of {common.OUT_REPS}")
    return jnp.ldexp(v, jnp.broadcast_to(-(su + sc), v.shape))
