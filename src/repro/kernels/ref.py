"""Pure-jnp oracles for the Pallas kernels.

Two tiers of reference:
  * ``*_f64`` — the true float64 result (accuracy oracle; the §2.5 error bound is
    asserted against this).
  * ``repro.core.ozaki2.emulated_matmul`` — the unfused XLA implementation of the
    same arithmetic; the fused kernels in f64 output mode must match it
    BIT-EXACTLY (same scaling, same residues, same Garner), which pins down every
    integer step of the kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm_f64(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float64), b.astype(jnp.float64))


def gemv_f64(a: jax.Array, x: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float64), x.astype(jnp.float64))


def stencil7_f64(u: jax.Array, c: jax.Array) -> jax.Array:
    """7-point stencil with zero halo; c = [centre, -x, +x, -y, +y, -z, +z]."""
    u = u.astype(jnp.float64)
    c = c.astype(jnp.float64)
    z = jnp.zeros_like(u)

    def shift(arr, ax, d):
        return jnp.roll(arr, d, axis=ax)

    def masked(arr, ax, d):
        rolled = jnp.roll(arr, d, axis=ax)
        idx = [slice(None)] * 3
        idx[ax] = 0 if d == 1 else -1
        rolled = rolled.at[tuple(idx)].set(0.0)
        return rolled

    return (c[0] * u
            + c[1] * masked(u, 0, 1) + c[2] * masked(u, 0, -1)
            + c[3] * masked(u, 1, 1) + c[4] * masked(u, 1, -1)
            + c[5] * masked(u, 2, 1) + c[6] * masked(u, 2, -1))


def spmv_bell_f64(a_val: jax.Array, a_col: jax.Array, x: jax.Array) -> jax.Array:
    """Blocked-ELL SpMV oracle: y_i = sum_j a_val[i,j] * x[a_col[i,j]]."""
    gathered = x.astype(jnp.float64)[a_col]
    return jnp.sum(a_val.astype(jnp.float64) * gathered, axis=-1)
