"""Fused Ozaki-II Blocked-ELL SpMV Pallas kernel (paper §5.4, Algorithm 3).

y = A·x with A in Blocked-ELL layout: ``a_val (M, bw)`` padded nonzero values and
``a_col (M, bw)`` gather indices.  Each program handles a block of ``br`` rows:
stream the value block, gather x, residue-decompose both in VMEM, contract the
bw-length products per modulus, Garner, store.

TPU adaptation notes (DESIGN.md §3):
  * the dense vector x stays fully VMEM-resident as an (hi, lo) int32 pair
    (8 B/element; for N = 1M that is 8 MiB — well within v5e VMEM), which is the
    shared-memory-tile assumption of Algorithm 3;
  * the gather x[a_col] is expressed as a vector gather; on Mosaic this lowers to
    dynamic-gather (supported for minor-dim gathers) — the one-hot-matmul fallback
    documented in DESIGN.md is not needed in interpret mode;
  * β inherits the ELL padding ratio ρ_pad exactly as Appendix D derives — the
    kernel adds nothing on top (residues never touch HBM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import ozaki2, splitting
from repro.kernels import common
from repro.kernels.ozaki_stencil import _global_scale_to_int


def _spmv_kernel(av_hi_ref, av_lo_ref, col_ref, x_hi_ref, x_lo_ref, out_ref, *,
                 plan: ozaki2.Plan, out_rep: str):
    cols = col_ref[...]                      # (br, bw) int32
    xg_hi = x_hi_ref[...][cols]              # VMEM gather
    xg_lo = x_lo_ref[...][cols]

    a_res = common.residues_int32(av_hi_ref[...], av_lo_ref[...], plan.moduli)
    x_res = common.residues_int32(xg_hi, xg_lo, plan.moduli)

    accs = []
    for i, m in enumerate(plan.moduli):
        prod = a_res[i] * x_res[i]           # (br, bw) int32, |.| <= 128*128
        accs.append(common.balanced_mod(jnp.sum(prod, axis=-1), m))

    digits = common.garner_digits(accs, plan)
    if out_rep == "f64":
        out_ref[...] = common.digits_to_f64(digits, plan)
    elif out_rep == "ds":
        hi, lo = common.digits_to_ds(digits, plan)
        out_ref[0] = hi
        out_ref[1] = lo
    else:
        out_ref[...] = common.stack_digits_int8(digits)


def _decompose_operands(a_val: jax.Array, a_col: jax.Array, x: jax.Array,
                        plan: ozaki2.Plan):
    """Shared prologue of the fused kernel and the jnp reference: Phase-1
    scaling, hi/lo split, column cast.  One implementation keeps the two
    paths' bit-identity structural rather than a testing promise."""
    f64 = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    av, sa = splitting.scale_to_int(a_val.astype(f64), plan.payload_bits, axis=-1)
    xi, sx = _global_scale_to_int(x.astype(f64), plan.payload_bits)
    av_hi, av_lo = splitting.split_hi_lo(av)
    x_hi, x_lo = splitting.split_hi_lo(xi)
    return av_hi, av_lo, a_col.astype(jnp.int32), x_hi, x_lo, sa, sx


@functools.partial(jax.jit, static_argnames=("plan",))
def _spmv_ref_digits(a_val: jax.Array, a_col: jax.Array, x: jax.Array,
                     plan: ozaki2.Plan):
    """Reference front half: scaling, residues, contraction, Garner digits."""
    av_hi, av_lo, cols, x_hi, x_lo, sa, sx = _decompose_operands(
        a_val, a_col, x, plan)

    a_res = common.residues_int32(av_hi, av_lo, plan.moduli)
    x_res = common.residues_int32(x_hi[cols], x_lo[cols], plan.moduli)
    accs = [common.balanced_mod(jnp.sum(a_res[i] * x_res[i], axis=-1), m)
            for i, m in enumerate(plan.moduli)]
    return common.garner_digits(accs, plan), sa, sx


@functools.partial(jax.jit, static_argnames=("plan", "out_rep"))
def _spmv_ref_epilogue(digits, sa: jax.Array, sx: jax.Array,
                       plan: ozaki2.Plan, out_rep: str) -> jax.Array:
    """Reference back half: digit reconstruction + exact unscale."""
    f64 = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    if out_rep in ("f64", "digits"):
        y = common.digits_to_f64(digits, plan, out_dtype=f64)
    elif out_rep == "ds":
        hi, lo = common.digits_to_ds(digits, plan)
        y = hi.astype(f64) + lo.astype(f64)
    else:
        raise ValueError(f"out_rep must be one of {common.OUT_REPS}")
    return jnp.ldexp(y, jnp.broadcast_to(-(sa + sx), y.shape))


def spmv_bell_ref(a_val: jax.Array, a_col: jax.Array, x: jax.Array,
                  plan: ozaki2.Plan, out_rep: str = "f64") -> jax.Array:
    """Unfused jnp reference of the fused kernel's arithmetic, bit-identical.

    Same scaling, hi/lo split, residues, per-modulus contraction and Garner
    digits as ``_spmv_kernel`` — every integer step is exact and row-local, so
    the result matches the Pallas path bit-for-bit regardless of row blocking.
    This is the CPU fast path for tests and solvers: interpret-mode
    ``pl.pallas_call`` hands XLA a gather-heavy graph that costs minutes to
    compile (ROADMAP open item).

    Deliberately jitted as two stages split at the integer digit boundary: the
    combined residue graph + double-double reconstruction triggers a
    pathological XLA-CPU optimisation pass (minutes for r = 15), while the
    halves each compile in ~1 s.  The digits crossing the boundary are exact
    int32, so the split cannot change a single bit of the result.
    """
    digits, sa, sx = _spmv_ref_digits(a_val, a_col, x, plan)
    return _spmv_ref_epilogue(tuple(digits), sa, sx, plan, out_rep)


@functools.partial(jax.jit, static_argnames=("plan", "out_rep", "br", "interpret"))
def spmv_bell(a_val: jax.Array, a_col: jax.Array, x: jax.Array,
              plan: ozaki2.Plan, out_rep: str = "f64", br: int = 128,
              interpret: bool = True) -> jax.Array:
    M, bw = a_val.shape
    N = x.shape[0]
    f64 = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    br = min(br, M)
    pm = (-M) % br

    av_hi, av_lo, col, x_hi, x_lo, sa, sx = _decompose_operands(
        a_val, a_col, x, plan)
    if pm:
        av_hi = jnp.pad(av_hi, ((0, pm), (0, 0)))
        av_lo = jnp.pad(av_lo, ((0, pm), (0, 0)))
        col = jnp.pad(col, ((0, pm), (0, 0)))
        sa = jnp.pad(sa, (0, pm))
    Mp = M + pm
    grid = (Mp // br,)

    in_specs = [
        pl.BlockSpec((br, bw), lambda i: (i, 0)),
        pl.BlockSpec((br, bw), lambda i: (i, 0)),
        pl.BlockSpec((br, bw), lambda i: (i, 0)),
        pl.BlockSpec((N,), lambda i: (0,)),     # x fully VMEM-resident
        pl.BlockSpec((N,), lambda i: (0,)),
    ]
    if out_rep == "f64":
        out_shape = jax.ShapeDtypeStruct((Mp,), jnp.float64)
        out_spec = pl.BlockSpec((br,), lambda i: (i,))
    elif out_rep == "ds":
        out_shape = jax.ShapeDtypeStruct((2, Mp), jnp.float32)
        out_spec = pl.BlockSpec((2, br), lambda i: (0, i))
    elif out_rep == "digits":
        out_shape = jax.ShapeDtypeStruct((plan.r, Mp), jnp.int8)
        out_spec = pl.BlockSpec((plan.r, br), lambda i: (0, i))
    else:
        raise ValueError(f"out_rep must be one of {common.OUT_REPS}")

    kernel = functools.partial(_spmv_kernel, plan=plan, out_rep=out_rep)
    raw = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(av_hi, av_lo, col, x_hi, x_lo)

    if out_rep == "f64":
        y = raw[:M]
    elif out_rep == "ds":
        y = (raw[0].astype(f64) + raw[1].astype(f64))[:M]
    else:
        y = common.digits_to_f64(common.unstack_digits(raw), plan,
                                 out_dtype=f64)[:M]
    return jnp.ldexp(y, jnp.broadcast_to(-(sa[:M] + sx), y.shape))
