import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment deliverable e).

For every runnable (architecture × input-shape) cell, lower + compile the real
step function (train_step for train shapes, model.apply for prefill, decode_step
for decode shapes) against the production mesh — 16x16 single-pod and 2x16x16
multi-pod — with ShapeDtypeStruct inputs (no allocation), then record
memory_analysis / cost_analysis / collective bytes for the roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import registry
from repro.configs.base import SHAPES, SHAPES_BY_NAME
from repro.distributed import sharding
from repro.launch import cost_model, roofline
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.models.transformer import Model
from repro.optim import adamw
from repro.train.loop import make_train_step

from jax.sharding import NamedSharding, PartitionSpec as P


TRAIN_MICROBATCH = 4   # production default: fits the 16 GB/chip HBM budget


def _step_fn_and_specs(cfg, shape, model):
    """The pure step function + abstract inputs for this cell (no sharding)."""
    batch_specs = registry.input_specs(cfg, shape)
    if shape.kind == "train":
        params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        opt_shape = jax.eval_shape(adamw.adamw_init, params_shape)
        step = make_train_step(
            model, opt_cfg=adamw.AdamWConfig(moment_dtype="bfloat16"),
            microbatch=TRAIN_MICROBATCH, unroll=cfg.force_unroll)
        return step, (params_shape, opt_shape, batch_specs)
    if shape.kind == "prefill":
        params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        return model.apply, (params_shape, batch_specs)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    return model.decode_step, (params_shape, cache_shape,
                               batch_specs["tokens"], batch_specs["pos"])


def jaxpr_costs(arch, shape_name, policy="bf16"):
    """Exact global FLOPs + fusion-aware HBM traffic (both scan-aware), plus the
    inner-recurrence state-traffic correction for xLSTM-style mixers."""
    shape = SHAPES_BY_NAME[shape_name]
    cfg = registry.get_config(arch, policy_name=policy)
    fn, specs = _step_fn_and_specs(cfg, shape, Model(cfg))
    stats = cost_model.count(fn, *specs)
    flops = stats["flops"]
    hbm = stats["hbm_bytes"]
    scan_bytes = 0.0
    if any(b.mixer in ("mlstm", "slstm") for b in cfg.pattern):
        # re-trace with unrolled outer loops so only the truly-sequential inner
        # step recurrences contribute state traffic.  lstm_chunk -> whole seq:
        # a single S-step scan has identical state traffic to S/c chunks of c
        # steps, without unrolling thousands of chunk bodies at trace time.
        cfg_u = registry.get_config(arch, policy_name=policy, force_unroll=True,
                                    lstm_chunk=1 << 30)
        fn_u, specs_u = _step_fn_and_specs(cfg_u, shape, Model(cfg_u))
        scan_bytes = cost_model.count(fn_u, *specs_u)["scan_state_bytes"]
    return flops, hbm + scan_bytes


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               policy_name: str = "bf16", donate: bool = True,
               layout: str = "tp", microbatch: int = None,
               **cfg_overrides):
    """Lower + compile one (arch × shape × mesh) cell.  Returns (compiled, meta)."""
    cfg = registry.get_config(arch, policy_name=policy_name, **cfg_overrides)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = registry.cell_is_runnable(arch, shape)
    if not ok:
        raise SystemExit(f"SKIP {arch}/{shape_name}: {why}")

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chip_count(mesh)
    model = Model(cfg)
    # §Perf H2b (refuted): passing kvseq="data" to force cache-sharded decode
    # attention made GSPMD *re-gather* the cache for the masked write (641 GB
    # vs 248 GB) — the one-hot write alone (H2, 3.7x) is the keeper.  The
    # annotation path remains available for future iteration.
    sharding.install_annotations(cfg, mesh, layout, kvseq=None)

    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(model.init, key)
    pspecs = sharding.param_shardings(cfg, mesh, params_shape, layout)
    batch_specs = registry.input_specs(cfg, shape)

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig(moment_dtype="bfloat16")
        opt_shape = jax.eval_shape(
            lambda p: adamw.adamw_init(p, opt_cfg), params_shape)
        ospecs = sharding.opt_state_shardings(cfg, mesh, opt_shape,
                                              params_shape, layout)
        bspecs = sharding.batch_shardings(cfg, shape, mesh, batch_specs,
                                          layout)
        # cost-extraction compiles (force_unroll) use microbatch=1: identical
        # arithmetic volume, far smaller HLO (memory uses the production value).
        mb = microbatch or TRAIN_MICROBATCH
        step = make_train_step(model, opt_cfg=opt_cfg,
                               microbatch=1 if cfg.force_unroll else mb,
                               unroll=cfg.force_unroll)
        jitted = jax.jit(
            step,
            in_shardings=(pspecs, ospecs, bspecs),
            out_shardings=(pspecs, ospecs, None),
            donate_argnums=(0, 1) if donate else (),
        )
        lowered = jitted.lower(params_shape, opt_shape, batch_specs)
    elif shape.kind == "prefill":
        bspecs = sharding.batch_shardings(cfg, shape, mesh, batch_specs,
                                          layout)
        jitted = jax.jit(model.apply, in_shardings=(pspecs, bspecs))
        lowered = jitted.lower(params_shape, batch_specs)
    else:  # decode
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        cspecs = sharding.cache_shardings(cfg, mesh, cache_shape,
                                          shape.global_batch)
        tok = batch_specs["tokens"]
        pos = batch_specs["pos"]
        daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dax = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
        tok_sh = NamedSharding(
            mesh, P(dax, None) if shape.global_batch >= chips // mesh.shape["model"]
            else P())
        jitted = jax.jit(
            model.decode_step,
            in_shardings=(pspecs, cspecs, tok_sh, NamedSharding(mesh, P())),
            out_shardings=(None, cspecs),
            donate_argnums=(1,) if donate else (),
        )
        lowered = jitted.lower(params_shape, cache_shape, tok, pos)

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "chips": chips, "compile_s": compile_s,
            "policy": policy_name, "layout": layout}
    return compiled, cfg, shape, meta


def raw_costs(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    coll, by_kind = roofline.collective_bytes_from_hlo(compiled.as_text())
    return flops, hlo_bytes, coll, by_kind


def peak_bytes(compiled):
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            return float(
                getattr(ma, "temp_size_in_bytes", 0)
                + getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    return None


def scaled_costs(arch, shape_name, multi_pod, policy, cfg, layout="tp",
                 microbatch=None):
    """Scan-corrected bytes/collectives: XLA's HloCostAnalysis visits a while
    body ONCE, so the scanned full-depth compile under-counts by the trip count.
    We compile force-unrolled 1-period and 2-period variants (cheap — inner
    chunk loops unroll too) and scale:
        total = A + (B - A) * (num_layers/period - 1)
    The unrolled HLO per layer is identical to the scan body, so the scaling is
    exact up to boundary-fusion noise; non-divisible tails are prorated."""
    period = cfg.period
    # attn_chunk=0 (direct) and single SSM/LSTM chunks: byte-equivalent, much
    # smaller HLO (the inner-recurrence correction handles the time scans).
    simplify = dict(force_unroll=True, attn_chunk=0, ssm_chunk=1 << 30,
                    lstm_chunk=1 << 30)
    ov_a = dict(num_layers=period, **simplify)
    ov_b = dict(num_layers=2 * period, **simplify)
    if cfg.family == "encdec":
        ov_a["encoder_layers"] = 1
        ov_b["encoder_layers"] = 2
    ca, _, _, _ = lower_cell(arch, shape_name, multi_pod, policy_name=policy,
                             layout=layout, microbatch=microbatch, **ov_a)
    cb, _, _, _ = lower_cell(arch, shape_name, multi_pod, policy_name=policy,
                             layout=layout, microbatch=microbatch, **ov_b)
    fa, ba, cla, ka = raw_costs(ca)
    fb, bb, clb, kb = raw_costs(cb)
    reps = cfg.num_layers / period - 1.0
    flops = fa + (fb - fa) * reps
    hbytes = ba + (bb - ba) * reps
    coll = cla + (clb - cla) * reps
    kinds = {k: ka.get(k, 0.0) + (kb.get(k, 0.0) - ka.get(k, 0.0)) * reps
             for k in set(ka) | set(kb)}
    return flops, hbytes, coll, kinds


def run_cell(arch, shape_name, multi_pod, out_dir=None, policy="bf16",
             donate=True, costs="scaled", layout="tp", microbatch=None,
             tag_extra=""):
    """Full-depth compile (the deliverable: sharding coherence + memory) plus
    scan-corrected cost extraction for the roofline.

    Accounting: compiled-artifact numbers are PER-DEVICE (the SPMD-partitioned
    module); jaxpr FLOPs are GLOBAL.  Everything is stored as mesh totals so the
    assignment's term formulas (divide by chips) apply directly.
    """
    compiled, cfg, shape, meta = lower_cell(arch, shape_name, multi_pod,
                                            policy_name=policy, donate=donate,
                                            layout=layout,
                                            microbatch=microbatch)
    chips = meta["chips"]
    if costs == "scaled":
        _, xla_bytes_pd, coll_pd, kinds_pd = scaled_costs(
            arch, shape_name, multi_pod, policy, cfg, layout, microbatch)
        flops, hbytes = jaxpr_costs(arch, shape_name, policy)
        coll = coll_pd * chips
        kinds = {k: v * chips for k, v in kinds_pd.items()}
        kinds["xla_bytes_accessed_crosscheck"] = xla_bytes_pd * chips
    else:
        flops_pd, hbytes_pd, coll_pd, kinds_pd = raw_costs(compiled)
        flops = flops_pd * chips
        hbytes = hbytes_pd * chips
        coll = coll_pd * chips
        kinds = {k: v * chips for k, v in kinds_pd.items()}
    rep = roofline.CellReport(
        arch=arch, shape=shape_name, mesh=meta["mesh"], chips=chips,
        hlo_flops=flops, hlo_bytes=hbytes, collective_bytes=coll,
        collective_by_kind=kinds, per_device_peak_bytes=peak_bytes(compiled),
        model_flops=roofline.model_flops_for(cfg, shape),
    ).finish()
    rec = {**rep.to_json(), **meta}
    print(json.dumps(rec))
    try:
        ma = compiled.memory_analysis()
        print(f"# memory_analysis: {ma}", file=sys.stderr)
    except Exception as e:
        print(f"# memory_analysis unavailable: {e}", file=sys.stderr)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{meta['mesh']}" + \
            (f"_{policy}" if policy != "bf16" else "") + \
            (f"_{layout}" if layout != "tp" else "") + tag_extra
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=registry.list_archs())
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in SHAPES])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--policy", default="bf16")
    ap.add_argument("--layout", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--no-donate", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    cells = (registry.runnable_cells() if args.all
             else [(args.arch, SHAPES_BY_NAME[args.shape])])
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                # roofline costs are single-pod; multi-pod proves the "pod"
                # axis shards (compile success + memory) with raw costs only.
                run_cell(arch, shape.name, mp, out_dir=args.out,
                         policy=args.policy, donate=not args.no_donate,
                         costs="raw" if mp else "scaled",
                         layout=args.layout, microbatch=args.microbatch)
            except SystemExit as e:
                print(str(e), file=sys.stderr)
            except Exception:
                failures.append((arch, shape.name, mp))
                traceback.print_exc()
    if failures:
        print(f"FAILED cells: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
