"""Render the §Perf hypothesis→change→measure log from baseline + perf JSONs.

    PYTHONPATH=src python -m repro.launch.perf_report
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, Optional

from repro.core import tme


def load(path: str) -> Optional[Dict]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def frac(r: Dict) -> float:
    useful = r["model_flops"] / (r["chips"] * tme.PEAK_BF16_FLOPS)
    return useful / max(r["compute_s"], r["memory_s"], r["collective_s"])


def bound_ms(r: Dict) -> float:
    return 1e3 * max(r["compute_s"], r["memory_s"], r["collective_s"])


def diff_row(name: str, base: Dict, new: Dict, hypothesis: str) -> str:
    imp = bound_ms(base) / bound_ms(new) if bound_ms(new) else float("inf")
    peak_b = base.get("per_device_peak_bytes") or 0
    peak_n = new.get("per_device_peak_bytes") or 0
    return (
        f"### {name}\n"
        f"*Hypothesis*: {hypothesis}\n\n"
        f"| | compute ms | memory ms | collective ms | dominant | bound ms | "
        f"roofline frac | peak GB/dev |\n|---|---|---|---|---|---|---|---|\n"
        f"| before | {base['compute_s']*1e3:.2f} | {base['memory_s']*1e3:.2f} | "
        f"{base['collective_s']*1e3:.2f} | {base['dominant']} | "
        f"{bound_ms(base):.2f} | {frac(base):.4f} | {peak_b/1e9:.1f} |\n"
        f"| after | {new['compute_s']*1e3:.2f} | {new['memory_s']*1e3:.2f} | "
        f"{new['collective_s']*1e3:.2f} | {new['dominant']} | "
        f"{bound_ms(new):.2f} | {frac(new):.4f} | {peak_n/1e9:.1f} |\n\n"
        f"*Measured*: bound time {bound_ms(base):.2f} -> {bound_ms(new):.2f} ms "
        f"(**{imp:.1f}x**); roofline fraction {frac(base):.4f} -> "
        f"{frac(new):.4f}.\n"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--base", default="experiments/dryrun")
    ap.add_argument("--perf", default="experiments/perf")
    args = ap.parse_args()

    cases = [
        ("H1 yi-6b/train_4k: FSDP(ZeRO-3) layout instead of TP=16",
         "yi-6b_train_4k_16x16.json", "yi-6b_train_4k_16x16_fsdp.json",
         "TP=16 pays ~2 f32 (B,S,d) all-reduces per layer (~646 GB/dev/step); "
         "pure ZeRO-3 over all 256 chips replaces them with per-layer bf16 "
         "weight all-gathers (~8 GB/dev/step) — predict ~30x collective cut, "
         "new bound = memory term."),
        ("H2 gemma3-4b/long_500k: one-hot masked cache write",
         "gemma3-4b_long_500k_16x16.json", "gemma3-4b_long_500k_16x16.json",
         "dynamic_update_slice on the sequence-sharded KV ring buffer makes "
         "GSPMD reshuffle the cache through 688 GB of all-to-all per token; an "
         "elementwise one-hot masked write is local under any sharding — "
         "predict the all-to-all term vanishes and the cell becomes "
         "memory/latency-bound (the correct regime for decode)."),
        ("H3 qwen2-vl-72b/train_4k: FSDP layout + microbatch 8",
         "qwen2-vl-72b_train_4k_16x16.json",
         "qwen2-vl-72b_train_4k_16x16_fsdp.json",
         "At 72B the TP=16 all-reduces cost 59.5 s/step and the cell misses "
         "HBM (75 GB/dev).  ZeRO-3 weight gathers cost ~72e9*2B*3/256 = 1.7 "
         "GB/dev; microbatch 8 halves activation peaks — predict fits + "
         ">5x bound cut."),
        ("H4 yi-6b/train_4k under the paper-faithful ozaki2_int8 policy",
         "yi-6b_train_4k_16x16.json", "yi-6b_train_4k_16x16_ozaki2_int8.json",
         "Routing every weight matmul through Ozaki-II multiplies matmul "
         "FLOPs by alpha=r(k) and adds residue/Garner elementwise work; the "
         "TME model predicts the compute term grows ~16x while memory/"
         "collective stay put — measuring alpha end-to-end on a full training "
         "step validates the paper's Def. 1 cost model at system scale."),
    ]
    for name, b, n, hyp in cases:
        base = load(os.path.join(args.base, b))
        new = load(os.path.join(args.perf, n))
        if base and new:
            print(diff_row(name, base, new, hyp))
        else:
            print(f"### {name}\n(pending: {b if not base else n})\n")


if __name__ == "__main__":
    main()
