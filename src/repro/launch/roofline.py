"""Roofline term extraction from compiled dry-run artifacts (assignment §ROOFLINE).

Terms (seconds, per the assignment's TPU v5e constants):
    compute    = HLO_FLOPs / (chips * 197e12)
    memory     = HLO_bytes / (chips * 819e9)
    collective = collective_bytes / (chips * 50e9)

collective_bytes is parsed from the *post-SPMD* optimized HLO (compiled.as_text())
— GSPMD materialises the collectives there — summing the moved bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute, with
per-op traffic weights (all-reduce counts 2x: reduce-scatter + all-gather phases).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.core import tme

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# result-type(s) then opcode, e.g.:
#   %ag = bf16[8,1024]{1,0} all-gather(bf16[8,64]{1,0} %x), ...
#   %t  = (f32[8]{0}, f32[8]{0}) all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+?)\s+(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    """Sum per-device moved bytes over all collectives in optimized HLO."""
    by_kind: Dict[str, float] = {}
    for m in _OP_RE.finditer(hlo_text):
        result_type, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":       # paired with -start; count once
            continue
        nbytes = _type_bytes(result_type)
        if kind == "all-reduce":
            moved = 2 * nbytes               # reduce-scatter + all-gather phases
        elif kind == "all-gather":
            moved = nbytes                   # ring: recv ~= result bytes
        else:                                # reduce-scatter / a2a / permute
            moved = nbytes
        by_kind[kind] = by_kind.get(kind, 0.0) + moved
    return sum(by_kind.values()), by_kind


@dataclasses.dataclass
class CellReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collective_by_kind: Dict[str, float]
    per_device_peak_bytes: Optional[float]
    model_flops: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    useful_ratio: float = 0.0

    def finish(self) -> "CellReport":
        terms = tme.roofline_terms(self.hlo_flops, self.hlo_bytes,
                                   self.collective_bytes, self.chips)
        self.compute_s = terms.compute_s
        self.memory_s = terms.memory_s
        self.collective_s = terms.collective_s
        self.dominant = terms.dominant
        self.useful_ratio = (self.model_flops / self.hlo_flops
                             if self.hlo_flops else 0.0)
        return self

    @property
    def roofline_fraction(self) -> float:
        """Useful compute time / bound time — the score the perf pass moves."""
        bound = max(self.compute_s, self.memory_s, self.collective_s)
        useful_s = self.model_flops / (self.chips * tme.PEAK_BF16_FLOPS)
        return useful_s / bound if bound > 0 else 0.0

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for train (N = active params, D = tokens);
    2*N*D for forward-only prefill; 2*N*batch for one decode step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch    # decode: one token per sequence


def fft_stage_terms(n: int, batch: int = 1, chips: int = 1,
                    params: Optional[tme.EmulationParams] = None,
                    spec: Optional[tme.ChipSpec] = None
                    ) -> List[Tuple[str, tme.RooflineTerms, float]]:
    """Per-stage roofline terms of the Bailey four-step FFT (spectral section).

    Returns (stage_name, three-term RooflineTerms, gamma_seconds) per stage:
    the compute/memory terms come from the stage (W, Q) scaled by the TME
    emulation parameters, the gamma term is the per-stage Garner reconstruction
    latency — the knob the companion paper's gamma-roof analysis turns.  When
    no params are given, gamma comes from the ``tme.garner_gamma`` model (alpha
    doubles as r for the Ozaki-II defaults) so the term is not silently zero.
    """
    spec = spec or tme.TPU_V5E
    if params is None:
        base = tme.EmulationParams.ozaki2()
        params = dataclasses.replace(
            base, gamma=tme.garner_gamma(spec, int(base.alpha)))
    p_low = tme.p_low(spec, params.substrate) * 1e12
    out = []
    for s in tme.bailey_fft_stages(n, batch):
        terms = tme.roofline_terms(
            params.alpha * s.W, params.beta * s.Q, 0.0, chips,
            peak_flops=p_low, hbm_bw=spec.hbm_tbps * 1e12)
        out.append((s.name, terms, params.gamma * s.n_out))
    return out


def render_markdown_row(r: CellReport) -> str:
    return (f"| {r.arch} | {r.shape} | {r.mesh} | "
            f"{r.hlo_flops:.3g} | {r.hlo_bytes:.3g} | {r.collective_bytes:.3g} | "
            f"{r.compute_s * 1e3:.2f} | {r.memory_s * 1e3:.2f} | "
            f"{r.collective_s * 1e3:.2f} | {r.dominant} | "
            f"{r.useful_ratio:.3f} | {r.roofline_fraction:.3f} |")
