"""Exact FLOP (and scan-state-traffic) accounting from the jaxpr.

XLA's HloCostAnalysis visits a ``while`` body ONCE, so any lax.scan (layer stack,
time recurrence, chunk loop) is under-counted by its trip count in
``compiled.cost_analysis()``.  The jaxpr, by contrast, carries every scan's
static ``length`` — walking it gives exact totals:

    flops             2·m·n·k per dot_general (+1/elem for elementwise float ops),
                      scan bodies multiplied by length, cond branches averaged.
    hbm_bytes         fusion-aware HBM traffic model: operand+result bytes of
                      every dot_general / pallas_call (matmul tiles stream
                      through VMEM; operands and results cross HBM once),
                      input bytes of reductions, result bytes of gathers /
                      dynamic slices; pure elementwise chains are assumed fused
                      (TPU XLA behaviour) and cost nothing.  This is the memory
                      term of the roofline — the CPU backend's ``bytes accessed``
                      lacks TPU-grade fusion and is reported separately as a
                      cross-check only.
    scan_state_bytes  Σ over scan eqns: length × (2 × carry bytes + per-step
                      xs/ys slice bytes) — sequential-loop state traffic.
                      Computed on the force_unroll jaxpr so only genuinely-
                      sequential inner recurrences (mLSTM/sLSTM steps) contribute.

Counts are GLOBAL (pre-SPMD logical shapes); the dry-run divides by the mesh size.
"""

from __future__ import annotations

from typing import Dict

import jax
import numpy as np

_ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "neg", "abs", "sign",
    "exp", "log", "tanh", "logistic", "rsqrt", "sqrt", "pow", "erf",
    "floor", "ceil", "round", "integer_pow", "select_n", "rem",
    "exp2", "log1p", "expm1", "cos", "sin", "atan2",
}
_REDUCTIONS = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
               "argmax", "argmin", "cumsum", "cumlogsumexp", "cummax",
               "cumprod", "reduce_and", "reduce_or"}
_GATHERISH = {"gather", "dynamic_slice", "dynamic_update_slice", "take",
              "scatter", "scatter-add", "scatter_add", "concatenate", "sort"}


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 0


def _bytes(aval) -> int:
    try:
        return _size(aval) * aval.dtype.itemsize
    except Exception:
        return 0


def _dot_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    batch = 1.0
    for d in lb:
        batch *= a.shape[d]
    contract = 1.0
    for d in lc:
        contract *= a.shape[d]
    lfree = 1.0
    for i, s in enumerate(a.shape):
        if i not in lc and i not in lb:
            lfree *= s
    rfree = 1.0
    for i, s in enumerate(b.shape):
        if i not in rc and i not in rb:
            rfree *= s
    return 2.0 * batch * contract * lfree * rfree


def _sub_jaxprs(eqn):
    """(jaxpr, multiplier) pairs for call-like primitives."""
    name = eqn.primitive.name
    p = eqn.params
    if name == "scan":
        return [(p["jaxpr"], float(p["length"]))]
    if name == "while":
        body = [(p["body_jaxpr"], 1.0)]          # trips unknown: counted once
        if "cond_jaxpr" in p:
            body.append((p["cond_jaxpr"], 1.0))
        return body
    if name == "cond":
        return [(br, 1.0 / len(p["branches"])) for br in p["branches"]]
    out = []
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in p:
            out.append((p[key], 1.0))
    if "branches" in p and not out:
        out = [(br, 1.0 / len(p["branches"])) for br in p["branches"]]
    return out


def _walk(jaxpr, stats: Dict[str, float]) -> None:
    if hasattr(jaxpr, "jaxpr"):                   # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        subs = _sub_jaxprs(eqn)
        if subs:
            sub_stats_total: Dict[str, float] = {}
            for sub, mult in subs:
                s: Dict[str, float] = {"flops": 0.0, "scan_state_bytes": 0.0,
                                       "hbm_bytes": 0.0}
                _walk(sub, s)
                for k in s:
                    sub_stats_total[k] = sub_stats_total.get(k, 0.0) + \
                        s[k] * mult
            for k, v in sub_stats_total.items():
                stats[k] = stats.get(k, 0.0) + v
            if name == "scan":
                length = float(eqn.params["length"])
                ncar = eqn.params["num_carry"]
                ncon = eqn.params["num_consts"]
                carry_b = sum(_bytes(v.aval)
                              for v in eqn.invars[ncon:ncon + ncar])
                xs_b = sum(_bytes(v.aval) // max(int(v.aval.shape[0]), 1)
                           for v in eqn.invars[ncon + ncar:]
                           if v.aval.shape)
                ys_b = sum(_bytes(v.aval) // max(int(v.aval.shape[0]), 1)
                           for v in eqn.outvars[ncar:] if v.aval.shape)
                stats["scan_state_bytes"] = stats.get("scan_state_bytes", 0.0) \
                    + length * (2.0 * carry_b + xs_b + ys_b)
            continue
        if name == "dot_general":
            stats["flops"] = stats.get("flops", 0.0) + _dot_flops(eqn)
            stats["hbm_bytes"] = stats.get("hbm_bytes", 0.0) + sum(
                _bytes(v.aval) for v in list(eqn.invars) + list(eqn.outvars))
        elif name == "pallas_call":
            stats["hbm_bytes"] = stats.get("hbm_bytes", 0.0) + sum(
                _bytes(v.aval) for v in list(eqn.invars) + list(eqn.outvars))
        elif name in _ELEMENTWISE:
            stats["flops"] = stats.get("flops", 0.0) + sum(
                _size(v.aval) for v in eqn.outvars)
        elif name in _REDUCTIONS:
            stats["flops"] = stats.get("flops", 0.0) + sum(
                _size(v.aval) for v in eqn.invars)
            stats["hbm_bytes"] = stats.get("hbm_bytes", 0.0) + sum(
                _bytes(v.aval) for v in eqn.invars)
        elif name in _GATHERISH:
            stats["hbm_bytes"] = stats.get("hbm_bytes", 0.0) + sum(
                _bytes(v.aval) for v in eqn.outvars)


def count(fn, *example_args, **kw) -> Dict[str, float]:
    """Trace fn with ShapeDtypeStruct/abstract args and return exact totals."""
    jaxpr = jax.make_jaxpr(fn)(*example_args, **kw)
    stats: Dict[str, float] = {"flops": 0.0, "scan_state_bytes": 0.0,
                               "hbm_bytes": 0.0}
    _walk(jaxpr, stats)
    return stats
