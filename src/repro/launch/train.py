"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ck

Runs the full production loop on whatever devices exist: sharded params (on a
host mesh), deterministic sharded data pipeline, AdamW + warmup/cosine, periodic
async checkpoints, straggler monitor, resume-from-latest.  With --smoke it uses
the reduced config (CPU-friendly); without, the full config (TPU pod).
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import registry
from repro.data.pipeline import DataConfig, Pipeline
from repro.distributed import sharding
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import Model
from repro.optim import adamw
from repro.train import checkpoint, fault_tolerance
from repro.train.loop import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=registry.list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--policy", default="bf16")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = registry.get_config(args.arch, smoke=args.smoke,
                              policy_name=args.policy)
    model = Model(cfg)
    mesh = make_host_mesh(data=1, model=1)
    sharding.install_annotations(cfg, mesh)

    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=args.lr)
    opt_state = adamw.adamw_init(params, opt_cfg)
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M policy={cfg.policy_name}")

    step_fn = jax.jit(make_train_step(
        model, opt_cfg, warmup_steps=max(args.steps // 10, 1),
        total_steps=args.steps, compress_grads=args.compress_grads,
        microbatch=args.microbatch))

    data = Pipeline(DataConfig(global_batch=args.batch, seq_len=args.seq),
                    cfg, start_step=0)
    writer = checkpoint.AsyncWriter()
    monitor = fault_tolerance.StragglerDetector(num_hosts=1)

    start = 0
    if args.ckpt_dir and checkpoint.latest_step(args.ckpt_dir) is not None:
        state = {"params": params, "opt": opt_state}
        state, extra = checkpoint.restore(args.ckpt_dir, like=state)
        params, opt_state = state["params"], state["opt"]
        start = int(extra.get("next_step", 0))
        data = Pipeline(DataConfig(global_batch=args.batch, seq_len=args.seq),
                        cfg, start_step=start)
        print(f"resumed from step {start}")

    compress_state = None
    for step in range(start, args.steps):
        batch = next(data)
        t0 = time.perf_counter()
        if args.compress_grads:
            params, opt_state, metrics, compress_state = step_fn(
                params, opt_state, batch, compress_state)
        else:
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        import numpy as np
        monitor.observe(np.asarray([dt]))
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            writer.save(args.ckpt_dir, step + 1,
                        {"params": params, "opt": opt_state},
                        extra={"next_step": step + 1})
    writer.wait()
    data.close()
    print("done")


if __name__ == "__main__":
    main()
