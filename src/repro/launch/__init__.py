"""repro.launch subpackage."""
