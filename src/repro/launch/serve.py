"""Serving driver: continuous-batching engine over a (reduced or full) config.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
        --requests 6 --slots 2 --max-new 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models.transformer import Model
from repro.serve.engine import ContinuousBatcher, Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b", choices=registry.list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = registry.get_config(args.arch, smoke=args.smoke)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=args.slots,
                         max_seq=args.max_seq)
    batcher = ContinuousBatcher(engine)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for uid in range(args.requests):
        batcher.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size,
                                args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new))
    done = batcher.run_to_completion(max_steps=2000)
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    for r in sorted(done, key=lambda r: r.uid):
        print(f"req {r.uid}: {list(r.prompt)} -> {r.generated}")
    print(f"{len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s on {jax.default_backend()})")


if __name__ == "__main__":
    main()
