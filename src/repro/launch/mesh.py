"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches JAX device state — the dry-run script owns the 512-device host
platform configuration; tests and benches see the default single device.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (data, model); 2 pods = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    devs = np.asarray(jax.devices()[: data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


def mesh_chip_count(mesh: Mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        n *= mesh.shape[a]
    return n
