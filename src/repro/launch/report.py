"""Render EXPERIMENTS.md roofline tables from the dry-run JSON artifacts.

    PYTHONPATH=src python -m repro.launch.report --dir experiments/dryrun
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from repro.core import tme

HEADER = ("| arch | shape | mesh | HLO FLOPs | HBM bytes | coll bytes | "
          "compute ms | memory ms | coll ms | dominant | 6ND/HLO | "
          "roofline frac | fits 16GB |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|---|---|")


def row(r: Dict) -> str:
    useful_s = r["model_flops"] / (r["chips"] * tme.PEAK_BF16_FLOPS)
    bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
    frac = useful_s / bound if bound else 0.0
    peak = r.get("per_device_peak_bytes")
    fits = "?" if peak is None else ("yes" if peak < 16e9 else
                                     f"NO ({peak/1e9:.0f}GB)")
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['hlo_flops']:.3g} | {r['hlo_bytes']:.3g} | "
            f"{r['collective_bytes']:.3g} | "
            f"{r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} | "
            f"{r['collective_s']*1e3:.2f} | {r['dominant']} | "
            f"{r['useful_ratio']:.3f} | {frac:.4f} | {fits} |")


def load(dirname: str) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    recs = [r for r in load(args.dir) if r["mesh"] == args.mesh
            and r.get("policy", "bf16") == "bf16"]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    print(HEADER)
    for r in recs:
        print(row(r))
    # summary: worst roofline fraction / most collective-bound
    def frac(r):
        useful = r["model_flops"] / (r["chips"] * tme.PEAK_BF16_FLOPS)
        return useful / max(r["compute_s"], r["memory_s"], r["collective_s"])
    if recs:
        worst = min(recs, key=frac)
        collb = max(recs, key=lambda r: r["collective_s"]
                    / max(r["compute_s"], r["memory_s"], 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']} "
              f"= {frac(worst):.4f}")
        print(f"most collective-bound: {collb['arch']}/{collb['shape']} "
              f"(coll/compute = "
              f"{collb['collective_s']/max(collb['compute_s'],1e-12):.1f}x)")


if __name__ == "__main__":
    main()
