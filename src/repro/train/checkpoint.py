"""Sharded, async, atomic checkpointing with integrity manifest.

Layout (one directory per step):
    ckpt_dir/step_000123/
        host0000.npz        flattened param/opt leaves owned by this host
        MANIFEST.json       tree structure, leaf->file map, fletcher checksums,
                            mesh shape, data step — written LAST (commit point)
Restores are atomic: a step directory without a MANIFEST is ignored (crash during
write), so restart always finds the latest *complete* checkpoint.  ``AsyncWriter``
runs saves on a background thread (compute/IO overlap) with a bounded queue.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any
MANIFEST = "MANIFEST.json"


def _flatten(tree: Pytree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree: Pytree, host_id: int = 0,
         extra: Optional[Dict] = None) -> str:
    """Synchronous sharded save with atomic manifest commit."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    flat = _flatten(tree)
    fname = f"host{host_id:04d}.npz"
    tmp_name = os.path.join(d, f".tmp_host{host_id:04d}.npz")  # savez appends
    with open(tmp_name, "wb") as f:                            # .npz unless we
        np.savez(f, **flat)                                    # hand it a file
    os.replace(tmp_name, os.path.join(d, fname))
    checksums = {k: zlib.adler32(v.tobytes()) for k, v in flat.items()}
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "files": {fname: sorted(flat)},
        "checksums": checksums,
        "treedef": str(treedef),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
    }
    tmp = os.path.join(d, MANIFEST + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, os.path.join(d, MANIFEST))   # commit point
    return d


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and \
                os.path.exists(os.path.join(ckpt_dir, name, MANIFEST)):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Pytree, step: Optional[int] = None,
            host_id: int = 0, verify: bool = True) -> Tuple[Pytree, Dict]:
    """Restore into the structure of ``like``; returns (tree, manifest.extra)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, f"host{host_id:04d}.npz"))
    if verify:
        for k in data.files:
            if zlib.adler32(data[k].tobytes()) != manifest["checksums"][k]:
                raise IOError(f"checksum mismatch for leaf {k} in {d}")
    flat_like = _flatten(like)
    assert set(flat_like) == set(data.files), "checkpoint/model structure mismatch"
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten(like))
    restored = [jnp.asarray(data[k]) for k in keys]
    # keys order == tree_flatten_with_path order == tree_flatten order
    tree = jax.tree_util.tree_unflatten(treedef, restored)
    return tree, manifest.get("extra", {})


def cleanup(ckpt_dir: str, keep: int = 3) -> None:
    """Retain only the newest ``keep`` complete checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_")
        and os.path.exists(os.path.join(ckpt_dir, n, MANIFEST)))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


class AsyncWriter:
    """Background checkpoint writer: save() returns immediately; wait() joins."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, ckpt_dir: str, step: int, tree: Pytree, **kw) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot off-device

        def _run():
            try:
                save(ckpt_dir, step, host_tree, **kw)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
