"""Fault tolerance for multi-pod training: heartbeats, straggler detection,
restart policy.  Designed for 1000+ nodes; exercised here with simulated hosts.

Components:
  * HeartbeatMonitor — per-host liveness with configurable timeout; a host that
    misses ``timeout_s`` is declared dead and a re-mesh is requested.
  * StragglerDetector — per-step wall-time EWMA + z-score across hosts; hosts
    slower than ``z_thresh`` sigma for ``patience`` consecutive steps are flagged
    for eviction (the TPU equivalent of SLURM drain + elastic re-mesh).
  * RestartPolicy — exponential-backoff restart budget; integrates with
    checkpoint.latest_step for resume-from-latest.
  * run_with_recovery — the driver loop: wraps a step function, checkpoints
    periodically, and on (simulated or real) failure restores the latest
    complete checkpoint and continues.  This is the single-process analogue of
    the k8s/GKE "jobset restart" pattern; the checkpoint/restore machinery is
    identical in the real deployment.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.train import checkpoint


@dataclasses.dataclass
class HeartbeatMonitor:
    num_hosts: int
    timeout_s: float = 60.0
    _last: Dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, host_id: int, now: Optional[float] = None) -> None:
        self._last[host_id] = time.monotonic() if now is None else now

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [h for h in range(self.num_hosts)
                if now - self._last.get(h, -1e18) > self.timeout_s]

    @property
    def healthy(self) -> bool:
        return not self.dead_hosts()


@dataclasses.dataclass
class StragglerDetector:
    num_hosts: int
    alpha: float = 0.1            # EWMA smoothing
    z_thresh: float = 3.0
    patience: int = 5
    _ewma: Optional[np.ndarray] = None
    _flags: Optional[np.ndarray] = None

    def observe(self, step_times: np.ndarray) -> List[int]:
        """step_times: (num_hosts,) wall seconds for this step.
        Returns hosts flagged as stragglers (>= patience consecutive hits)."""
        if self._ewma is None:
            self._ewma = step_times.astype(np.float64).copy()
            self._flags = np.zeros(self.num_hosts, np.int32)
            return []
        self._ewma = (1 - self.alpha) * self._ewma + self.alpha * step_times
        # robust z-score (median/MAD) so the straggler can't inflate the spread
        med = np.median(self._ewma)
        mad = np.median(np.abs(self._ewma - med)) * 1.4826 + 1e-6 * med + 1e-12
        z = (self._ewma - med) / mad
        hit = z > self.z_thresh
        self._flags = np.where(hit, self._flags + 1, 0)
        return [int(h) for h in np.nonzero(self._flags >= self.patience)[0]]


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 10
    backoff_s: float = 1.0
    backoff_mult: float = 2.0
    restarts: int = 0

    def next_delay(self) -> Optional[float]:
        if self.restarts >= self.max_restarts:
            return None
        d = self.backoff_s * (self.backoff_mult ** self.restarts)
        self.restarts += 1
        return d


class StepFailure(RuntimeError):
    """Raised by a step function to signal a recoverable worker failure."""


def run_with_recovery(step_fn: Callable[[int, Any], Tuple[Any, Dict]],
                      init_state: Any, num_steps: int, ckpt_dir: str,
                      ckpt_every: int = 10,
                      policy: Optional[RestartPolicy] = None,
                      sleep: Callable[[float], None] = time.sleep
                      ) -> Tuple[Any, Dict]:
    """Run ``state, metrics = step_fn(step, state)`` for num_steps with
    checkpoint/restart recovery.  Returns (final_state, stats)."""
    policy = policy or RestartPolicy()
    writer = checkpoint.AsyncWriter()
    stats = {"failures": 0, "restores": 0, "steps_run": 0}

    state = init_state
    step = 0
    start = checkpoint.latest_step(ckpt_dir)
    if start is not None:
        state, extra = checkpoint.restore(ckpt_dir, like=init_state)
        step = int(extra.get("next_step", start + 1))
        stats["restores"] += 1

    while step < num_steps:
        try:
            state, _ = step_fn(step, state)
            stats["steps_run"] += 1
            step += 1
            if step % ckpt_every == 0 or step == num_steps:
                writer.save(ckpt_dir, step, state, extra={"next_step": step})
        except StepFailure:
            stats["failures"] += 1
            delay = policy.next_delay()
            if delay is None:
                writer.wait()
                raise
            sleep(delay)
            writer.wait()
            last = checkpoint.latest_step(ckpt_dir)
            if last is not None:
                state, extra = checkpoint.restore(ckpt_dir, like=init_state)
                step = int(extra.get("next_step", last))
                stats["restores"] += 1
            else:
                state, step = init_state, 0
    writer.wait()
    return state, stats
