"""Training step & loop: loss, gradients, clipping, AdamW, optional gradient
compression, microbatch accumulation — all jax.lax control flow, pjit-compatible.

``make_train_step(model)`` returns the pure function lowered by the dry-run:
(params, opt_state, batch) -> (params, opt_state, metrics).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from repro.optim import adamw
from repro.optim.schedules import linear_warmup_cosine

Pytree = Any


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       z_loss: float = 1e-4) -> jax.Array:
    """Next-token CE (logits f32 (B,S,V), labels (B,S)) with z-loss.

    The gold logit is extracted with a one-hot contraction rather than
    take_along_axis: under a vocab-sharded logits layout the contraction stays
    local + a tiny all-reduce, where a gather would force GSPMD to all-gather
    the full logits (16 GB/device at 64k vocab).
    """
    from repro.distributed.annotate import ann
    shift_logits = logits[:, :-1]
    shift_labels = labels[:, 1:]
    logz = jax.nn.logsumexp(shift_logits, axis=-1)
    onehot = ann(jax.nn.one_hot(shift_labels, logits.shape[-1],
                                dtype=shift_logits.dtype),
                 ("batch", None, "vocab"))
    gold = jnp.einsum("bsv,bsv->bs", shift_logits, onehot)
    ce = jnp.mean(logz - gold)
    return ce + z_loss * jnp.mean(logz ** 2)


def make_loss_fn(model: Model, aux_weight: float = 0.01) -> Callable:
    def loss_fn(params: Pytree, batch: Dict) -> Tuple[jax.Array, Dict]:
        logits, aux = model.apply(params, batch)
        ce = cross_entropy_loss(logits, batch["labels"])
        loss = ce + aux_weight * aux
        return loss, {"ce": ce, "aux": aux}
    return loss_fn


def make_train_step(model: Model,
                    opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                    warmup_steps: int = 100, total_steps: int = 10_000,
                    compress_grads: bool = False,
                    microbatch: int = 1, unroll: bool = False) -> Callable:
    """Build the train_step.  ``microbatch`` > 1 accumulates gradients over
    sequential microbatches — the standard memory/throughput trade.  Batches
    are split *strided* ((B//mb, mb) -> swap) so each device keeps its own rows
    and no resharding collective is introduced.  ``compress_grads`` routes
    gradients through the int8 error-feedback compressor.  ``unroll`` uses a
    python loop for the accumulation (exact dry-run cost accounting)."""
    loss_fn = make_loss_fn(model)

    def single_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, metrics, grads

    def train_step(params: Pytree, opt_state: Dict, batch: Dict,
                   compress_state: Optional[Pytree] = None):
        if microbatch > 1:
            from repro.distributed.annotate import ann

            def split(x):
                y = x.reshape((-1, microbatch) + x.shape[1:]).swapaxes(0, 1)
                return y

            mbatches = jax.tree.map(split, batch)

            def one(mb):
                mb = {k: ann(v, ("batch",) + (None,) * (v.ndim - 1))
                      for k, v in mb.items()}
                return single_grads(params, mb)

            if unroll:
                zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                    params)
                gsum, losses = zero, []
                for i in range(microbatch):
                    mb = jax.tree.map(lambda t: t[i], mbatches)
                    loss, _, grads = one(mb)
                    gsum = jax.tree.map(jnp.add, gsum, grads)
                    losses.append(loss)
                grads = jax.tree.map(lambda g: g / microbatch, gsum)
                loss = jnp.mean(jnp.stack(losses))
            else:
                def body(acc, mb):
                    loss, _, grads = one(mb)
                    return jax.tree.map(jnp.add, acc, grads), loss

                zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                    params)
                gsum, losses = jax.lax.scan(body, zero, mbatches)
                grads = jax.tree.map(lambda g: g / microbatch, gsum)
                loss = jnp.mean(losses)
            metrics = {}
        else:
            loss, metrics, grads = single_grads(params, batch)

        if compress_grads:
            from repro.distributed import compression
            grads, compress_state = compression.compress_decompress(
                grads, compress_state)

        grads, gnorm = adamw.clip_by_global_norm(grads, opt_cfg.grad_clip_norm)
        lr_scale = linear_warmup_cosine(opt_state["step"] + 1, warmup_steps,
                                        total_steps)
        params, opt_state = adamw.adamw_update(params, grads, opt_state,
                                               opt_cfg, lr_scale)
        out_metrics = {"loss": loss, "grad_norm": gnorm,
                       "lr_scale": jnp.asarray(lr_scale, jnp.float32)}
        out_metrics.update({k: v for k, v in metrics.items()})
        if compress_grads:
            return params, opt_state, out_metrics, compress_state
        return params, opt_state, out_metrics

    return train_step


def make_eval_step(model: Model) -> Callable:
    loss_fn = make_loss_fn(model)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return {"loss": loss, **metrics}

    return eval_step
