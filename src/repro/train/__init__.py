"""repro.train subpackage."""
