"""repro.optim subpackage."""
