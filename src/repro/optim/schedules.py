"""Learning-rate schedules (jax.lax-friendly: step -> scale multipliers)."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup_cosine(step, warmup_steps: int, total_steps: int,
                         min_ratio: float = 0.1):
    s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(s / max(warmup_steps, 1), 1.0)
    prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos


def constant(step):
    return 1.0
