"""AdamW with optional reduced-precision moments (bfloat16 m/v).

At jamba-1.5-large scale (398B params) on a 256-chip pod, f32 Adam moments alone
would be 12.4 GB/device; bf16 moments halve that (DESIGN.md §5 memory budget).
State shards identically to the parameters (same NamedSharding tree), giving the
ZeRO-style fully-sharded optimizer for free under GSPMD.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"      # "bfloat16" for the large-model budget
    grad_clip_norm: float = 1.0


def adamw_init(params: Pytree, cfg: AdamWConfig = AdamWConfig()) -> Dict:
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Tuple[Pytree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(params: Pytree, grads: Pytree, state: Dict,
                 cfg: AdamWConfig = AdamWConfig(),
                 lr_scale: jax.Array | float = 1.0) -> Tuple[Pytree, Dict]:
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    newm = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    newv = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return newp, {"m": newm, "v": newv, "step": step}
