"""repro.data subpackage."""
