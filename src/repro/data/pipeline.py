"""Deterministic, shard-aware, step-resumable synthetic token pipeline.

Production shape: each data-parallel host generates only its shard of the global
batch (host_id-keyed counter-based RNG), so the pipeline is (a) deterministic
given (seed, step) — restart-safe without data-state checkpoints beyond the step
counter, (b) O(1) state — elastic re-sharding just changes the host->shard map,
(c) prefetchable via a background thread (double buffering).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


def _host_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    # counter-based: (seed, step, host) fully determines the batch
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))


def synth_batch(cfg: DataConfig, model_cfg: ModelConfig, step: int) -> Dict:
    """Markov-chain synthetic tokens (learnable structure, not pure noise)."""
    rng = _host_rng(cfg, step)
    B, S, V = cfg.host_batch, cfg.seq_len, model_cfg.vocab_size
    # simple order-1 structure: next = (prev * a + noise) % V with shared a
    a = 6364136223846793005 % V or 1
    x = np.empty((B, S + 1), np.int64)
    x[:, 0] = rng.integers(0, V, B)
    noise = rng.integers(0, max(V // 64, 2), (B, S))
    for t in range(S):
        x[:, t + 1] = (x[:, t] * a + noise[:, t]) % V
    tokens = x[:, :-1].astype(np.int32)
    labels = x[:, 1:].astype(np.int32)
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    if model_cfg.family == "encdec":
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((B, model_cfg.encoder_seq, model_cfg.d_model)),
            jnp.bfloat16)
    if model_cfg.frontend == "vision":
        batch.pop("tokens")
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((B, S, model_cfg.d_model)), jnp.bfloat16)
        batch["positions"] = jnp.asarray(
            np.broadcast_to(np.arange(S), (B, 3, S)).copy(), jnp.int32)
    return batch


class Pipeline:
    """Step-indexed iterator with background prefetch (double buffering)."""

    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig,
                 start_step: int = 0, prefetch: int = 2):
        self.cfg = cfg
        self.model_cfg = model_cfg
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            batch = synth_batch(self.cfg, self.model_cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict]:
        return self

    def __next__(self) -> Dict:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
