"""Bailey four-step FFT factorisation over the dispatch seam (Part 2, §3).

For composite n = n1·n2 the DFT factors into two passes of batched *small*
dense DFT GEMMs around a diagonal twiddle scaling and a transpose:

    X[k2·n1 + k1] = Σ_j2 omega_n2^(j2·k2) · omega_n^(j2·k1)
                        · Σ_j1 omega_n1^(j1·k1) x[j1·n2 + j2]

  1. view x as an (n1, n2) matrix (row-major),
  2. DFT each column — one (n1, n1) GEMM over n2·batch stacked columns,
  3. scale by the twiddle table W[k1, j2] = omega_n^(±k1·j2) (elementwise,
     working precision — the one non-GEMM arithmetic stage),
  4. transpose and DFT each row — one (n2, n2) GEMM over n1·batch columns,
  5. read the output transposed.

Both GEMM passes recurse through ``dft_stacked``, so large lengths factor all
the way down to DENSE_MAX-sized dense operators and *every* multiplication in
the subsystem flows through ``repro.core.dispatch``.  Prime lengths fall back
to the dense operator (bounded by ``dft.DENSE_HARD_MAX``).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.spectral import dft


def choose_factors(n: int) -> Optional[Tuple[int, int]]:
    """Balanced factorisation n = n1·n2 with n1 <= n2, or None if n is prime.

    n1 is the largest divisor at or below sqrt(n), which keeps both GEMM passes
    near the square (minimum total MACs ~ 8·n·(n1 + n2)·batch).
    """
    for d in range(int(math.isqrt(n)), 1, -1):
        if n % d == 0:
            return d, n // d
    return None


def dft_stacked(x: jax.Array, inverse: bool = False,
                mode: Optional[str] = None) -> jax.Array:
    """Unnormalised DFT along axis 0 of a complex (n, batch) stack.

    Dense single-GEMM below ``dft.DENSE_MAX`` (and for prime n); Bailey
    four-step with recursive factor transforms above it.
    """
    n, batch = x.shape
    if n <= 1:
        return x.astype(dft.working_complex())
    factors = choose_factors(n) if n > dft.DENSE_MAX else None
    if factors is None:
        return dft.dft_dense(x, inverse=inverse, mode=mode)
    n1, n2 = factors

    # Step 1+2: column DFTs of the (n1, n2) view, batched as one GEMM.
    a = x.reshape(n1, n2 * batch)
    b = dft_stacked(a, inverse=inverse, mode=mode)
    # Step 3: twiddle scaling (elementwise complex, working precision).
    b = b.reshape(n1, n2, batch) * dft.twiddle(n, n1, n2, inverse)[:, :, None]
    # Step 4: transpose, then row DFTs as the second GEMM pass.
    c = jnp.moveaxis(b, 1, 0).reshape(n2, n1 * batch)
    d = dft_stacked(c, inverse=inverse, mode=mode)
    # Step 5: the output is read transposed: X[k2·n1 + k1] = D[k2, k1].
    return d.reshape(n, batch)
