"""Public spectral-transform API (matches the ``jnp.fft`` conventions).

All transforms compose the stacked axis-0 DFT of ``bailey.dft_stacked``:
  * ``fft`` / ``ifft``    — 1-D complex transforms along any axis,
  * ``fft2`` / ``fftn``   — multi-dimensional transforms by axis composition,
  * ``rfft`` / ``irfft``  — real-input / Hermitian-output transforms.

Normalisation follows numpy/jax: ``fft`` is unnormalised, ``ifft`` carries the
1/n factor, ``irfft(rfft(x), n) == x``.  ``mode`` forwards to the dispatch
layer (None inherits ``REPRO_DISPATCH`` / ``dispatch.mode_scope``), so a single
``with dispatch.mode_scope("pallas")`` flips every GEMM in a transform onto the
fused kernel route.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.spectral import bailey, dft


def _apply_along_axis(x: jax.Array, axis: int, inverse: bool,
                      mode: Optional[str]) -> jax.Array:
    """DFT along ``axis``: move it to the front, flatten the rest as batch."""
    x = jnp.moveaxis(jnp.asarray(x), axis, 0).astype(dft.working_complex())
    shp = x.shape
    out = bailey.dft_stacked(x.reshape(shp[0], -1), inverse=inverse, mode=mode)
    return jnp.moveaxis(out.reshape(shp), 0, axis)


def fft(x: jax.Array, axis: int = -1, mode: Optional[str] = None) -> jax.Array:
    """Unnormalised complex DFT along ``axis`` (the ``jnp.fft.fft`` contract)."""
    return _apply_along_axis(x, axis, inverse=False, mode=mode)


def ifft(x: jax.Array, axis: int = -1, mode: Optional[str] = None) -> jax.Array:
    """Inverse DFT along ``axis`` with the 1/n normalisation."""
    x = jnp.asarray(x)
    n = x.shape[axis]
    return _apply_along_axis(x, axis, inverse=True, mode=mode) / n


def _resolve_axes(ndim: int, axes: Optional[Sequence[int]]) -> Tuple[int, ...]:
    if axes is None:
        return tuple(range(ndim))
    return tuple(int(a) for a in axes)


def fftn(x: jax.Array, axes: Optional[Sequence[int]] = None,
         mode: Optional[str] = None) -> jax.Array:
    """N-dimensional DFT by axis composition (default: all axes)."""
    x = jnp.asarray(x)
    for a in _resolve_axes(x.ndim, axes):
        x = fft(x, axis=a, mode=mode)
    return x


def ifftn(x: jax.Array, axes: Optional[Sequence[int]] = None,
          mode: Optional[str] = None) -> jax.Array:
    x = jnp.asarray(x)
    for a in _resolve_axes(x.ndim, axes):
        x = ifft(x, axis=a, mode=mode)
    return x


def fft2(x: jax.Array, axes: Tuple[int, int] = (-2, -1),
         mode: Optional[str] = None) -> jax.Array:
    return fftn(x, axes=axes, mode=mode)


def ifft2(x: jax.Array, axes: Tuple[int, int] = (-2, -1),
          mode: Optional[str] = None) -> jax.Array:
    return ifftn(x, axes=axes, mode=mode)


def rfft(x: jax.Array, axis: int = -1, mode: Optional[str] = None) -> jax.Array:
    """Real-input DFT: the n//2 + 1 non-redundant coefficients along ``axis``.

    Computed as the full complex transform sliced to the Hermitian half — the
    realified GEMM already carries the zero imaginary block exactly, so the
    sliced result matches ``jnp.fft.rfft`` at the same accuracy as ``fft``.
    """
    x = jnp.asarray(x)
    if jnp.iscomplexobj(x):
        raise ValueError("rfft requires real input (matching jnp.fft.rfft); "
                         "use fft for complex operands")
    n = x.shape[axis]
    full = fft(x, axis=axis, mode=mode)
    idx = [slice(None)] * full.ndim
    idx[axis if axis >= 0 else full.ndim + axis] = slice(0, n // 2 + 1)
    return full[tuple(idx)]


def irfft(x: jax.Array, n: Optional[int] = None, axis: int = -1,
          mode: Optional[str] = None) -> jax.Array:
    """Inverse of ``rfft``: Hermitian-extend the half spectrum, inverse-DFT,
    return the real part (length ``n``, default 2·(m − 1) for m coefficients)."""
    x = jnp.asarray(x).astype(dft.working_complex())
    ax = axis if axis >= 0 else x.ndim + axis
    m = x.shape[ax]
    if n is None:
        n = 2 * (m - 1)
    # numpy semantics: the half spectrum is truncated or zero-padded to the
    # n//2 + 1 coefficients the length-n transform actually uses.
    need = n // 2 + 1
    if m > need:
        head = [slice(None)] * x.ndim
        head[ax] = slice(0, need)
        x = x[tuple(head)]
    elif m < need:
        widths = [(0, 0)] * x.ndim
        widths[ax] = (0, need - m)
        x = jnp.pad(x, widths)
    m = need
    k_mirror = n - jnp.arange(m, n)          # n-k in [1, m-1]: always in range
    head = [slice(None)] * x.ndim
    head[ax] = slice(0, m)
    tail = jnp.conj(jnp.take(x, k_mirror, axis=ax))
    full = jnp.concatenate([x[tuple(head)], tail], axis=ax)
    return jnp.real(ifft(full, axis=ax, mode=mode))


def dft_error_bound(n: int) -> float:
    """Crude forward relative-error model for the emulated transform: the
    dispatch GEMM is correctly rounded, so the bound is the twiddle/stage term
    ~ u·(number of four-step levels + 1)·sqrt(n)."""
    u = 2.0 ** -53 if jax.config.jax_enable_x64 else 2.0 ** -24
    levels = 1
    nn = n
    while nn > dft.DENSE_MAX and bailey.choose_factors(nn) is not None:
        nn = bailey.choose_factors(nn)[1]
        levels += 1
    return u * levels * (float(n) ** 0.5)
