"""Dense DFT-as-GEMM on the emulation dispatch seam (companion paper, Part 2).

The spectral subsystem's ground rule: the *only* multiplications are matrix
products routed through ``repro.core.dispatch``, so every transform inherits the
Ozaki-II accuracy contract (and the XLA/Pallas routing, plan cache, and TPU
story) of the dispatch layer for free.

A length-n complex DFT is one real GEMM here.  With F = Fr + i·Fi the complex
product F·X splits into the "realified" block form

    [Cr]   [Fr  -Fi] [Xr]
    [Ci] = [Fi   Fr]·[Xi]

so the (2n, 2n) block operator is built once per (n, direction, dtype), cached
on device, and applied to the stacked real/imag operand with a single
``dispatch.matmul`` call — four real matmuls' worth of MACs in one fused kernel
launch, with one plan resolution for the 2n-length contraction.

Twiddle/DFT entries are generated in float64 with exact argument reduction
(j·k mod n in int64) so the operator itself contributes O(u) per entry; the
emulated GEMM then reproduces the correctly-rounded FP64 contraction.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch

# Transforms at or below this length run as a single dense DFT GEMM; longer
# lengths go through the Bailey four-step factorisation (repro.spectral.bailey).
DENSE_MAX = 64

# Hard cap on the dense fallback (taken only when n has no usable factorisation,
# i.e. prime n): an (2n, 2n) operator above this is a memory bug, not a path.
DENSE_HARD_MAX = 4096


def working_float():
    return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32


def working_complex():
    return jnp.complex128 if jax.config.jax_enable_x64 else jnp.complex64


def _roots_of_unity(row: np.ndarray, col: np.ndarray, n: int,
                    inverse: bool) -> np.ndarray:
    """omega_n^(±row·col) with exact int64 argument reduction mod n."""
    jk = np.mod(np.outer(row.astype(np.int64), col.astype(np.int64)), n)
    sign = 2.0 if inverse else -2.0
    ang = sign * np.pi * jk.astype(np.float64) / float(n)
    return np.cos(ang) + 1j * np.sin(ang)


def dft_matrix(n: int, inverse: bool = False) -> np.ndarray:
    """Unnormalised complex DFT matrix F[j, k] = omega_n^(±jk), float64."""
    idx = np.arange(n)
    return _roots_of_unity(idx, idx, n, inverse)


# Realified operators above this length are built on demand instead of cached:
# the composite path only ever needs factor-sized operators (<= DENSE_MAX), but
# the prime fallback could otherwise pin an unbounded set of (2n, 2n) f64
# arrays (n = 4093 alone is ~536 MB) on device for the process lifetime.
CACHE_MAX = 4 * DENSE_MAX


def _build_realified(n: int, inverse: bool, dtype_name: str) -> jax.Array:
    f = dft_matrix(n, inverse)
    blk = np.block([[f.real, -f.imag], [f.imag, f.real]])
    return jnp.asarray(blk, dtype=jnp.dtype(dtype_name))


@functools.lru_cache(maxsize=None)
def _realified_dft(n: int, inverse: bool, dtype_name: str) -> jax.Array:
    """(2n, 2n) realified block operator [[Fr, -Fi], [Fi, Fr]], device-cached."""
    return _build_realified(n, inverse, dtype_name)


def realified_dft(n: int, inverse: bool = False) -> jax.Array:
    if n > DENSE_HARD_MAX:
        raise ValueError(
            f"dense DFT fallback refused for n={n} > {DENSE_HARD_MAX} "
            "(prime length with no four-step factorisation; pad to a "
            "composite length instead)")
    dtype_name = jnp.dtype(working_float()).name
    if n > CACHE_MAX:
        return _build_realified(int(n), bool(inverse), dtype_name)
    return _realified_dft(int(n), bool(inverse), dtype_name)


# Twiddle tables above this n (16n bytes each) are built on demand instead of
# cached — the same unbounded-device-pinning guard as CACHE_MAX below.
TWIDDLE_CACHE_MAX = 1 << 16


def _build_twiddle(n: int, n1: int, n2: int, inverse: bool,
                   dtype_name: str) -> jax.Array:
    w = _roots_of_unity(np.arange(n1), np.arange(n2), n, inverse)
    return jnp.asarray(w, dtype=jnp.dtype(dtype_name))


@functools.lru_cache(maxsize=None)
def _twiddle(n: int, n1: int, n2: int, inverse: bool,
             dtype_name: str) -> jax.Array:
    """(n1, n2) four-step twiddle W[k1, j2] = omega_n^(±k1·j2), device-cached."""
    return _build_twiddle(n, n1, n2, inverse, dtype_name)


def twiddle(n: int, n1: int, n2: int, inverse: bool = False) -> jax.Array:
    dtype_name = jnp.dtype(working_complex()).name
    if n > TWIDDLE_CACHE_MAX:
        return _build_twiddle(int(n), int(n1), int(n2), bool(inverse),
                              dtype_name)
    return _twiddle(int(n), int(n1), int(n2), bool(inverse), dtype_name)


def cache_clear() -> None:
    """Drop the cached DFT operators and twiddle tables (tests / x64 toggles)."""
    _realified_dft.cache_clear()
    _twiddle.cache_clear()


def dft_dense(x: jax.Array, inverse: bool = False,
              mode: Optional[str] = None) -> jax.Array:
    """Unnormalised DFT along axis 0 of a stacked (n, batch) complex operand.

    One realified GEMM through the dispatch layer: stack real over imag parts
    into a (2n, batch) real operand, multiply by the cached (2n, 2n) block
    operator, and re-interleave the halves as the complex result.
    """
    n = x.shape[0]
    wf = working_float()
    op = realified_dft(n, inverse)
    xb = jnp.concatenate([jnp.real(x), jnp.imag(x)], axis=0).astype(wf)
    out = dispatch.matmul(op, xb, mode=mode)
    return jax.lax.complex(out[:n], out[n:]).astype(working_complex())
