"""Spectral-transform subsystem: Ozaki-Bailey FFT on the FP8 dispatch seam.

Every multiplication in this package is a matrix product routed through
``repro.core.dispatch`` (dense DFT GEMMs below ``dft.DENSE_MAX``, Bailey
four-step factorisation above it), so the transforms inherit the emulated-FP64
accuracy contract and the XLA/Pallas routing of the dispatch layer.
"""

from repro.spectral.bailey import choose_factors, dft_stacked
from repro.spectral.dft import DENSE_MAX, dft_matrix, realified_dft, twiddle
from repro.spectral.fft import (dft_error_bound, fft, fft2, fftn, ifft, ifft2,
                                ifftn, irfft, rfft)

__all__ = [
    "DENSE_MAX", "choose_factors", "dft_error_bound", "dft_matrix",
    "dft_stacked", "fft", "fft2", "fftn", "ifft", "ifft2", "ifftn", "irfft",
    "realified_dft", "rfft", "twiddle",
]
