"""Conjugate Gradient with the paper's post-FP64 kernel stack (§7.1(a)).

The audit's recipe for iterative solvers on FP64-starved hardware:
  * the SpMV (the dominant cost) runs through the fused Ozaki-II Blocked-ELL
    kernel at FP64-equivalent accuracy,
  * the BLAS-1 reductions (dot products, norms) run on the healthy vector pipe
    with compensated accumulation (``repro.core.compensated``) — "B300's FP32
    pipe is well above the BLAS-1 memory-roof requirement; not binding",
  * no iterative-refinement outer loop is needed: the emulated SpMV inherits
    the componentwise error bound of the emulated GEMM (§2.5).

The residual recurrence is driven by the compensated reductions; alongside it
the solver records the same quantities re-computed with plain working-precision
dots (``history_plain``) so the accuracy delta of the compensated path is
directly observable (tests/test_hpc_cg.py).

``cg_solve`` is generic over the matvec; ``cg_solve_bell`` wires in the
Blocked-ELL SpMV kernel and ``cg_solve_dense`` the dispatch-routed dense GEMV.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.core import compensated, dispatch, ozaki2
from repro.obs import telemetry as obs


@dataclasses.dataclass
class CGResult:
    x: jax.Array
    iters: int
    residual: float
    converged: bool
    history: list                 # compensated relative-residual recurrence
    history_plain: list = dataclasses.field(default_factory=list)
    # same reductions in plain working precision (observability, not control)


def cg_solve(matvec: Callable[[jax.Array], jax.Array], b: jax.Array,
             x0: Optional[jax.Array] = None, tol: float = 1e-10,
             maxiter: int = 500,
             dot: Callable = compensated.compensated_dot,
             norm: Callable = compensated.compensated_norm,
             record_plain: bool = True) -> CGResult:
    """Textbook CG; compensated reductions drive the recurrence and the stop
    test, a plain-dot shadow history records what uncompensated working
    precision would have reported for the same iterates.  ``record_plain=False``
    drops the shadow reduction (one extra O(n) dot + host sync per iteration)
    for production solves that never read it."""
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - matvec(x)
    p = r
    rs = dot(r, r)
    bnorm = norm(b)
    bnorm_plain = jnp.sqrt(jnp.dot(b, b)) if record_plain else None

    history: List[float] = [float(jnp.sqrt(rs) / bnorm)]
    history_plain: List[float] = []
    # Residual-trace telemetry: one event per recorded residual (iteration 0
    # included), so convergence trajectories are observable alongside the
    # per-op seam events the matvec itself records.
    obs.record_event("solver.cg", dims=b.shape, iter=0, rel_residual=history[0])
    if record_plain:
        history_plain.append(float(jnp.sqrt(jnp.dot(r, r)) / bnorm_plain))
    it = 0
    for it in range(1, maxiter + 1):
        ap = matvec(p)
        alpha = rs / dot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = dot(r, r)
        history.append(float(jnp.sqrt(rs_new) / bnorm))
        obs.record_event("solver.cg", dims=b.shape, iter=it,
                         rel_residual=history[-1])
        if record_plain:
            history_plain.append(float(jnp.sqrt(jnp.dot(r, r)) / bnorm_plain))
        if history[-1] < tol:
            return CGResult(x, it, history[-1], True, history, history_plain)
        p = r + (rs_new / rs) * p
        rs = rs_new
    return CGResult(x, it, history[-1], False, history, history_plain)


def cg_solve_bell(a_val: jax.Array, a_col: jax.Array, b: jax.Array,
                  plan: Optional[ozaki2.Plan] = None, out_rep: str = "f64",
                  mode: Optional[str] = None, **kw) -> CGResult:
    """CG with the Ozaki-II Blocked-ELL SpMV as the matvec, dispatch-routed.

    The plan resolves once from the dispatch cache (not per iteration); the
    SpMV route follows ``mode`` / ``mode_scope`` / ``REPRO_DISPATCH`` like
    every multiplication behind the seam — the sparse-LA dwarf's §7.1(a)
    recipe with the emulated kernel as a uniformly-routed drop-in.
    """
    if plan is None:
        plan = dispatch.get_plan(a_val.shape[1], margin_bits=4)

    def matvec(x):
        return dispatch.spmv(a_val, a_col, x, plan=plan, out_rep=out_rep,
                             mode=mode)
    return cg_solve(matvec, b, **kw)


def cg_solve_dense(a: jax.Array, b: jax.Array,
                   plan: Optional[ozaki2.Plan] = None,
                   mode: Optional[str] = None, **kw) -> CGResult:
    """CG on a dense SPD matrix with the emulated matvec routed through the
    dispatch layer (XLA reference or fused Pallas GEMM per ``mode`` /
    ``REPRO_DISPATCH``) — the §7.1(a) recipe for dense operators."""
    if plan is None:
        plan = dispatch.get_plan(a.shape[-1], margin_bits=4)

    def matvec(x):
        return dispatch.matmul(a, x[:, None], plan=plan, mode=mode)[:, 0]
    return cg_solve(matvec, b, **kw)
