"""Sparse-matrix format tooling for the Blocked-ELL SpMV kernel (paper §5.4).

``to_blocked_ell`` converts a dense/COO matrix to the (values, columns) padded
layout; ``padding_ratio`` is Appendix D's ρ_pad — the lower bound on the TME β
for the SpMV kernel.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def to_blocked_ell(dense: np.ndarray, bw: int) -> Tuple[np.ndarray, np.ndarray]:
    """Dense (M, N) -> (values (M, bw), columns (M, bw)); raises if a row has
    more than bw nonzeros.  Padded slots point at column 0 with value 0."""
    M, N = dense.shape
    val = np.zeros((M, bw), dense.dtype)
    col = np.zeros((M, bw), np.int32)
    for i in range(M):
        nz = np.nonzero(dense[i])[0]
        if len(nz) > bw:
            raise ValueError(f"row {i} has {len(nz)} > bw={bw} nonzeros")
        val[i, :len(nz)] = dense[i, nz]
        col[i, :len(nz)] = nz
    return val, col


def laplacian_1d(n: int) -> np.ndarray:
    return (np.diag(2.0 * np.ones(n)) - np.diag(np.ones(n - 1), 1)
            - np.diag(np.ones(n - 1), -1))


def laplacian_2d(nx: int, ny: int) -> np.ndarray:
    """5-point 2-D Laplacian, (nx*ny, nx*ny) SPD."""
    n = nx * ny
    a = np.zeros((n, n))
    for i in range(nx):
        for j in range(ny):
            k = i * ny + j
            a[k, k] = 4.0
            for di, dj in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                ii, jj = i + di, j + dj
                if 0 <= ii < nx and 0 <= jj < ny:
                    a[k, ii * ny + jj] = -1.0
    return a


def padding_ratio(val: np.ndarray) -> float:
    """Appendix D ρ_pad: stored slots / actual nonzeros (>= 1)."""
    stored = val.size
    actual = int(np.count_nonzero(val))
    return stored / max(actual, 1)
