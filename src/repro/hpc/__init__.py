"""repro.hpc subpackage."""
