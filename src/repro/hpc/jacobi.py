"""Weighted-Jacobi relaxation on the 7-point Laplacian — the structured-grid
dwarf composed into the solver layer.

The operator application (the dominant cost of any relaxation sweep) is the
fused Ozaki-II 7-point stencil behind the dispatch seam
(``repro.core.dispatch.stencil7``), so ``mode_scope`` / ``REPRO_DISPATCH``
flips every multiplication of the solver between the Pallas kernel and the
bit-identical jnp reference — the same contract as CG's SpMV and the spectral
Poisson solver.  The update itself is elementwise (healthy vector pipe, per
§7.1(a)); the stopping test uses compensated norms.

Discretisation: the second-order finite-difference Laplacian on a regular
grid with homogeneous Dirichlet boundary conditions (the stencil kernel's
zero halo *is* the boundary condition):

    (Δ_h u)_ijk = Σ_axis (u_{-} - 2 u + u_{+}) / h_axis²,  u = 0 outside.

``jacobi_solve`` solves Δ_h u = f by damped Jacobi:

    u ← u + ω D⁻¹ (f - Δ_h u),   D = diag(Δ_h) = -Σ_axis 2 / h_axis².

ω = 1 is classical Jacobi (spectral radius cos(π/(n+1)) per axis — fastest as
a standalone solver on small grids); ω = 2/3 is the standard multigrid
smoother weighting.  Validation: ``tests/test_jacobi.py`` checks the solution
against the spectral *direct* solver (``poisson.poisson_solve_dirichlet``,
the PR-4 FFT subsystem via odd extension), closing the loop between the
structured-grid and spectral dwarfs.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import compensated, dispatch, ozaki2
from repro.obs import telemetry as obs


def laplacian_coeffs(spacings: Optional[Sequence[float]] = None) -> jax.Array:
    """Stencil coefficients of the 3-D FD Laplacian in the kernel's
    [centre, -x, +x, -y, +y, -z, +z] ordering."""
    if spacings is None:
        spacings = [1.0] * 3
    hx, hy, hz = (float(h) for h in spacings)
    return jnp.asarray([
        -2.0 / hx**2 - 2.0 / hy**2 - 2.0 / hz**2,
        1.0 / hx**2, 1.0 / hx**2,
        1.0 / hy**2, 1.0 / hy**2,
        1.0 / hz**2, 1.0 / hz**2,
    ])


def apply_dirichlet_laplacian(u: jax.Array,
                              spacings: Optional[Sequence[float]] = None,
                              plan: Optional[ozaki2.Plan] = None,
                              mode: Optional[str] = None) -> jax.Array:
    """Δ_h u with zero-Dirichlet halo, through the dispatch-routed stencil."""
    return dispatch.stencil7(u, laplacian_coeffs(spacings), plan=plan,
                             mode=mode)


@dataclasses.dataclass
class JacobiResult:
    u: jax.Array
    iters: int
    residual: float               # final relative residual ||f - Δ_h u||/||f||
    converged: bool
    history: list                 # compensated relative-residual per sweep


def jacobi_solve(f: jax.Array,
                 spacings: Optional[Sequence[float]] = None,
                 omega: float = 1.0,
                 tol: float = 1e-8,
                 maxiter: int = 2000,
                 x0: Optional[jax.Array] = None,
                 plan: Optional[ozaki2.Plan] = None,
                 mode: Optional[str] = None,
                 check_every: int = 1) -> JacobiResult:
    """Solve Δ_h u = f (zero-Dirichlet) by ω-damped Jacobi relaxation.

    Every sweep applies the 7-point operator through the dispatch seam (one
    emulated stencil per iteration) and relaxes u ← u + ω D⁻¹ r.  The
    residual norm (compensated) is evaluated every ``check_every`` sweeps;
    ``history`` records it for each evaluation, starting with the initial
    residual.  The plan resolves once from the dispatch cache.
    """
    f = jnp.asarray(f)
    if f.ndim != 3:
        raise ValueError(f"jacobi_solve expects a 3-D grid, got shape {f.shape}")
    if plan is None:
        plan = dispatch.get_plan(8, margin_bits=4)
    c = laplacian_coeffs(spacings)
    diag = float(c[0])
    u = jnp.zeros_like(f) if x0 is None else x0

    fnorm = float(compensated.compensated_norm(f))
    fnorm = max(fnorm, 1e-300)

    def residual(u):
        return f - dispatch.stencil7(u, c, plan=plan, mode=mode)

    r = residual(u)
    rel = float(compensated.compensated_norm(r)) / fnorm
    history: List[float] = [rel]
    obs.record_event("solver.jacobi", dims=f.shape, iter=0, rel_residual=rel)
    if rel < tol:
        return JacobiResult(u, 0, rel, True, history)

    it = 0
    for it in range(1, maxiter + 1):
        u = u + (omega / diag) * r
        r = residual(u)
        if it % check_every == 0 or it == maxiter:
            rel = float(compensated.compensated_norm(r)) / fnorm
            history.append(rel)
            obs.record_event("solver.jacobi", dims=f.shape, iter=it,
                             rel_residual=rel)
            if rel < tol:
                return JacobiResult(u, it, rel, True, history)
    return JacobiResult(u, it, history[-1], False, history)
