"""Spectral Poisson solver on periodic grids — the composite-solver-layer demo.

Solves the second-order finite-difference Poisson problem

    Δ_h u = f,   periodic boundary conditions, zero-mean gauge,

by diagonalising the periodic discrete Laplacian in the Fourier basis: the
forward/inverse transforms are ``repro.spectral`` FFTs (every multiplication an
emulated GEMM through the dispatch seam) and the per-mode division uses the
exact eigenvalues

    lambda(k) = sum_axis (2 cos(2*pi*k_a / n_a) - 2) / h_a**2,

so the solve is a *direct* method: one forward transform, one diagonal scale,
one inverse transform — the FFT dwarf composed into the solver layer, next to
the iterative CG route of ``repro.hpc.cg``.

The zero mode is projected out (the periodic operator has a constant-vector
nullspace): the returned solution has zero mean and solves Δ_h u = f - mean(f).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import spectral
from repro.core import compensated


def laplacian_eigenvalues(shape: Sequence[int],
                          spacings: Optional[Sequence[float]] = None
                          ) -> np.ndarray:
    """Eigenvalues of the periodic FD Laplacian on a ``shape`` grid (numpy)."""
    if spacings is None:
        spacings = [1.0] * len(shape)
    lam = np.zeros(tuple(shape))
    for ax, (n, h) in enumerate(zip(shape, spacings)):
        k = np.arange(n)
        lam_1d = (2.0 * np.cos(2.0 * np.pi * k / n) - 2.0) / (h * h)
        bshape = [1] * len(shape)
        bshape[ax] = n
        lam = lam + lam_1d.reshape(bshape)
    return lam


@dataclasses.dataclass
class PoissonResult:
    u: jax.Array          # zero-mean solution
    residual: float       # ||Δ_h u - (f - mean f)|| / ||f - mean f|| (compensated)


def poisson_solve_periodic(f: jax.Array,
                           spacings: Optional[Sequence[float]] = None,
                           mode: Optional[str] = None) -> jax.Array:
    """Direct spectral solve of Δ_h u = f - mean(f) on a periodic grid.

    f: real array of any rank (each axis a periodic dimension).  ``mode``
    forwards to the dispatch layer for every GEMM inside the transforms.
    """
    f = jnp.asarray(f)
    lam = jnp.asarray(laplacian_eigenvalues(f.shape, spacings))
    fhat = spectral.fftn(f, mode=mode)
    # Zero mode: lambda = 0 exactly; project it out (zero-mean gauge).
    inv = jnp.where(lam != 0, 1.0 / jnp.where(lam != 0, lam, 1.0), 0.0)
    uhat = fhat * inv
    return jnp.real(spectral.ifftn(uhat, mode=mode))


def apply_periodic_laplacian(u: jax.Array,
                             spacings: Optional[Sequence[float]] = None
                             ) -> jax.Array:
    """Δ_h u with periodic wrap — the stencil the spectral solve inverts."""
    if spacings is None:
        spacings = [1.0] * u.ndim
    out = jnp.zeros_like(u)
    for ax, h in enumerate(spacings):
        out = out + (jnp.roll(u, 1, axis=ax) + jnp.roll(u, -1, axis=ax)
                     - 2.0 * u) / (h * h)
    return out


def poisson_solve_checked(f: jax.Array,
                          spacings: Optional[Sequence[float]] = None,
                          mode: Optional[str] = None) -> PoissonResult:
    """Solve and report the true relative residual (compensated norms)."""
    u = poisson_solve_periodic(f, spacings=spacings, mode=mode)
    rhs = jnp.asarray(f) - jnp.mean(jnp.asarray(f))
    res = apply_periodic_laplacian(u, spacings=spacings) - rhs
    denom = float(compensated.compensated_norm(rhs))
    rel = float(compensated.compensated_norm(res)) / max(denom, 1e-300)
    return PoissonResult(u=u, residual=rel)


def manufactured_rhs(shape: Tuple[int, ...],
                     spacings: Optional[Sequence[float]] = None,
                     seed: int = 0) -> Tuple[jax.Array, jax.Array]:
    """(f, u_exact) pair: draw a smooth zero-mean u, apply the operator."""
    rng = np.random.default_rng(seed)
    u = rng.standard_normal(shape)
    u = u - u.mean()
    u = jnp.asarray(u)
    return apply_periodic_laplacian(u, spacings=spacings), u


# ---------------------------------------------------------------------------
# Zero-Dirichlet direct solve by odd extension
# ---------------------------------------------------------------------------

def odd_extension(f: jax.Array) -> jax.Array:
    """Antisymmetric periodic extension: each axis n -> 2(n + 1).

    Along every axis the interior samples f_1..f_n (grid points 1..n of a
    0..n+1 Dirichlet grid) are embedded as

        [0, f_1, ..., f_n, 0, -f_n, ..., -f_1],

    which is odd about both boundary points.  The periodic FD Laplacian
    preserves this antisymmetry, so its zero-mean solution restricted to the
    interior solves the homogeneous-Dirichlet problem — the classical
    sine-transform reduction, here built on the emulated FFT.
    """
    f = jnp.asarray(f)
    for ax in range(f.ndim):
        zshape = list(f.shape)
        zshape[ax] = 1
        zero = jnp.zeros(zshape, f.dtype)
        f = jnp.concatenate([zero, f, zero, -jnp.flip(f, axis=ax)], axis=ax)
    return f


def poisson_solve_dirichlet(f: jax.Array,
                            spacings: Optional[Sequence[float]] = None,
                            mode: Optional[str] = None) -> jax.Array:
    """Direct spectral solve of Δ_h u = f with zero-Dirichlet boundaries.

    f holds the interior grid values (any rank); the returned u has the same
    shape and satisfies the 7-point/5-point/3-point zero-halo FD Laplacian —
    the operator ``repro.hpc.jacobi.apply_dirichlet_laplacian`` applies
    through the stencil kernel.  Internally: odd extension, periodic spectral
    solve (every GEMM through the dispatch seam), restriction.  The extended
    rhs has exactly zero mean, so no gauge projection is lost.
    """
    f = jnp.asarray(f)
    g = odd_extension(f)
    u = poisson_solve_periodic(g, spacings=spacings, mode=mode)
    sl = tuple(slice(1, n + 1) for n in f.shape)
    return u[sl]
