"""Logical sharding annotations for model internals (flax "logical axes" style).

GSPMD propagates shardings from weights into activations; for a few tensors that
propagation picks pathological layouts (e.g. sharding attention head_dim from a
fused QKV projection, which turns every score matrix into an all-reduce).  Model
code annotates those tensors with *logical* axis names; the launcher installs a
mesh + per-arch rule table before tracing, and ``ann`` becomes a
with_sharding_constraint.  With no mesh installed (unit tests, examples) it is a
no-op, keeping the model code mesh-agnostic.
"""

from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def set_mesh(mesh: Mesh, rules: Dict[str, object]) -> None:
    _STATE.mesh = mesh
    _STATE.rules = rules


def clear() -> None:
    _STATE.mesh = None
    _STATE.rules = None


def current_rules() -> Optional[Dict[str, object]]:
    return getattr(_STATE, "rules", None)


def rule_set(name: str) -> bool:
    """True iff a logical axis has a mesh mapping in the installed rules."""
    rules = getattr(_STATE, "rules", None)
    return bool(rules) and rules.get(name) is not None


def ann(x: jax.Array, axes: Tuple[Optional[str], ...]) -> jax.Array:
    """Constrain x's sharding by logical axis names (None = unconstrained dim)."""
    mesh = getattr(_STATE, "mesh", None)
    if mesh is None:
        return x
    rules = _STATE.rules or {}
    spec = P(*[rules.get(a) if a else None for a in axes])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
