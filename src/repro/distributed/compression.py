"""Gradient compression: int8 quantisation with error feedback (EF-SGD style).

At 1000+-node scale the gradient all-reduce dominates the step at small per-chip
batch; 4x compression (f32 -> int8 + per-tensor scale) cuts the collective bytes
4x.  Error feedback accumulates the quantisation residual locally and adds it to
the next step's gradient, preserving convergence (Karimireddy et al. 2019).

``compress_decompress`` simulates the wire format in-graph: under pjit the
quantised tensor is what crosses the ICI when gradients are reduce-scattered.
(Production note: pairing with a reduce-scatter of int8 then f32 all-gather is
the standard deployment; XLA emits that schedule when the update is sharded.)
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_state(grads: Pytree) -> Pytree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_decompress(grads: Pytree, ef_state: Optional[Pytree] = None
                        ) -> Tuple[Pytree, Pytree]:
    """Returns (decompressed grads as seen after the wire, new EF state)."""
    if ef_state is None:
        ef_state = init_state(grads)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = _quantize(g32)
        deq = _dequantize(q, s)
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree.map(one, grads, ef_state)
    newg = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    newe = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return newg, newe


def compression_ratio(grads: Pytree) -> float:
    """Wire-bytes ratio f32 -> int8(+scale)."""
    total = sum(g.size * 4 for g in jax.tree.leaves(grads))
    wire = sum(g.size * 1 + 4 for g in jax.tree.leaves(grads))
    return total / wire
