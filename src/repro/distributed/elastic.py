"""Elastic scaling: re-mesh a running job onto a different device count.

The data-parallel degree changes (node failure shrinks the pod; capacity growth
expands it); parameters and optimizer state are resharded onto the new mesh and
the data pipeline's host->shard map is recomputed.  Because the synthetic
pipeline is counter-based (data/pipeline.py), no data state moves at all.

``reshard`` works on any pytree: device_put with the new NamedSharding tree — on
real hardware XLA turns this into the minimal all-gather/slice exchange.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig
from repro.distributed import sharding

Pytree = Any


def make_mesh_for(devices, model_parallel: int) -> Mesh:
    """Build a (data, model) mesh from an arbitrary device list."""
    n = len(devices)
    assert n % model_parallel == 0, (n, model_parallel)
    arr = np.asarray(devices).reshape(n // model_parallel, model_parallel)
    return Mesh(arr, ("data", "model"))


def reshard(tree: Pytree, shardings: Pytree) -> Pytree:
    return jax.device_put(tree, shardings)


def elastic_remesh(cfg: ModelConfig, params: Pytree, opt_state,
                   new_devices, model_parallel: int
                   ) -> Tuple[Mesh, Pytree, Any]:
    """Re-mesh params+opt onto the surviving/new device set."""
    mesh = make_mesh_for(new_devices, model_parallel)
    ps = sharding.param_shardings(cfg, mesh, params)
    os_ = sharding.opt_state_shardings(cfg, mesh, opt_state, params)
    return mesh, reshard(params, ps), reshard(opt_state, os_)
