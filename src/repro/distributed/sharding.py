"""Sharding rules: ModelConfig + mesh -> NamedSharding trees for params, optimizer
state, batches, and caches (DESIGN.md §5).

Strategy (GSPMD partitioning via jax.jit in/out shardings):
  * batch/sequence axes  -> ("pod", "data") (pod folds into data parallelism)
  * embedding/vocab      -> "model"
  * attention q/k/v/o    -> heads on "model" when divisible, else head_dim
  * MLP                  -> column-parallel in, row-parallel out on "model"
  * MoE experts          -> expert axis on "model" (EP)
  * SSM/xLSTM inner dim  -> "model"
  * optimizer moments    -> same sharding as their parameter (fully-sharded)
  * KV caches            -> batch on ("pod","data"); kv-heads on "model" when
                            divisible, else replicated heads + sharded head_dim
  * long_500k (batch=1)  -> sequence sharding on "data" for train/prefill inputs
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec

Pytree = Any


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _msize(mesh: Mesh) -> int:
    return mesh.shape["model"]


def _div(n: int, d: int) -> bool:
    return n % d == 0


def attn_proj_spec(cfg: ModelConfig, mesh: Mesh, kv: bool) -> P:
    """PartitionSpec for (d_model, heads*head_dim) projection weights."""
    m = _msize(mesh)
    heads = cfg.num_kv_heads if kv else cfg.num_heads
    if _div(heads, m) or _div(heads * cfg.head_dim, m):
        return P(None, "model")      # shard the fused head axis
    return P("model", None)          # fall back: shard d_model (row-parallel)


def param_specs(cfg: ModelConfig, mesh: Mesh, params: Pytree,
                layout: str = "tp") -> Pytree:
    """Build a PartitionSpec tree matching the params tree structure.

    layout="tp" (baseline): tensor-parallel on "model" + FSDP of a remaining
    large dim on the data axes.  layout="fsdp" (beyond-paper optimisation, see
    EXPERIMENTS.md §Perf): no tensor parallelism at all — every weight is
    ZeRO-3-sharded across ALL mesh axes and gathered per layer at use; the
    per-layer activation all-reduces of TP disappear entirely.  The right
    choice is model-size dependent; both compile on every cell.
    """
    m = _msize(mesh)
    daxes = _data_axes(mesh)
    if layout == "fsdp":
        daxes = tuple(mesh.axis_names)          # shard params over everything
    dax: Any = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    dsize = 1
    for a in (daxes or ()):
        dsize *= mesh.shape[a]

    def _fsdp(leaf, lead_len: int, spec_axes: Tuple) -> Tuple:
        """Add the data axis to the first unsharded, divisible dim."""
        if dax is None:
            return spec_axes
        axes = list(spec_axes)
        for i, a in enumerate(axes):
            if a is None and leaf.shape[lead_len + i] % dsize == 0 and \
                    leaf.shape[lead_len + i] >= dsize:
                axes[i] = dax
                return tuple(axes)
        return tuple(axes)

    def _sanitize(leaf, full: Tuple) -> Tuple:
        """Drop any sharding a dimension can't actually support."""
        out = []
        for i, a in enumerate(full):
            if a is None:
                out.append(None)
                continue
            size = 1
            for ax in (a if isinstance(a, tuple) else (a,)):
                size *= mesh.shape[ax]
            out.append(a if leaf.shape[i] % size == 0 else None)
        return tuple(out)

    def spec_for(path: str, leaf) -> P:
        nd = leaf.ndim
        # stacked period params have a leading periods axis -> prepend None
        lead = (None,) if "stack" in path or path.startswith("encoder") else ()

        def mk(*axes):
            axes = axes + (None,) * (nd - len(lead) - len(axes))
            if layout == "fsdp":              # strip TP placements entirely
                axes = tuple(None if a == "model" else a for a in axes)
            full = _sanitize(leaf, lead + tuple(axes))
            if nd - len(lead) >= 2 or (layout == "fsdp" and nd - len(lead) >= 1):
                axes = _fsdp(leaf, len(lead), full[len(lead):])
                full = _sanitize(leaf, lead + tuple(axes))
            return P(*full[:nd])

        if "embed/table" in path or "lm_head" in path:
            # vocab on model: table (V, d) -> P("model", None); lm_head (d, V)
            if "lm_head" in path:
                return mk(None, "model")
            return mk("model", None)
        if "enc_pos" in path:
            return mk(None, None)
        if "norm" in path or path.endswith("scale"):
            return mk(None)
        if "router" in path:
            return mk(None, None)
        if "experts" in path:
            return mk("model", None, None)       # expert-parallel
        if "mixer/wq" in path or "mixer/wk" in path or "mixer/wv" in path or \
                "cross/wq" in path or "cross/wk" in path or "cross/wv" in path:
            kv = "/wk" in path or "/wv" in path
            base = attn_proj_spec(cfg, mesh, kv)
            return mk(*base)
        if "mixer/wo" in path or "cross/wo" in path:
            # (heads*head_dim, d_model): transpose of the qkv rule
            base = attn_proj_spec(cfg, mesh, kv=False)
            return mk(*reversed(tuple(base)))
        if "wi_gate" in path or "wi_up" in path or "in_proj" in path or \
                "up_proj" in path or "w_in" in path or "wq" in path or \
                "wk" in path or "wv" in path or "w_if" in path or \
                "x_proj" in path:
            return mk(None, "model")             # column parallel
        if "wo" in path or "out_proj" in path or "down_proj" in path or \
                "r_in" in path:
            return mk("model", None)             # row parallel
        if "conv_w" in path or "a_log" in path or "dt_bias" in path or \
                "d_skip" in path:
            # per-channel SSM params: shard the d_inner axis where present
            if nd - len(lead) >= 1 and _div(leaf.shape[-1], m):
                return mk(*([None] * (nd - len(lead) - 1) + ["model"]))
            return mk(*([None] * (nd - len(lead))))
        return mk(*([None] * (nd - len(lead))))

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    specs = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        specs[key] = spec_for(key, leaf)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)
            for path, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, [specs[k] for k in keys])


def param_shardings(cfg: ModelConfig, mesh: Mesh, params: Pytree,
                    layout: str = "tp") -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, mesh, params, layout))


def logical_rules(cfg: ModelConfig, mesh: Mesh,
                  layout: str = "tp", kvseq: Any = None) -> Dict[str, Any]:
    """Logical-axis -> mesh-axis table for in-model annotations (annotate.py).

    Attention strategy (tp): shard q-heads on "model" when divisible; otherwise
    fall back to context-parallel attention (sequence on "model", heads
    replicated) — never let GSPMD shard head_dim into the score contraction.
    fsdp layout: pure data parallelism — batch over ALL axes, no model axes.
    """
    m = _msize(mesh)
    daxes = _data_axes(mesh)
    if layout == "fsdp":
        alldax = tuple(mesh.axis_names)
        return {
            "batch": alldax if len(alldax) > 1 else alldax[0],
            "heads": None, "kv_heads": None, "aseq": None,
            "ff": None, "expert": None, "vocab": None, "kvseq": kvseq,
        }
    dax: Any = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    heads_ok = _div(cfg.num_heads, m)
    kv_ok = _div(cfg.num_kv_heads, m)
    return {
        "batch": dax,
        "heads": "model" if heads_ok else None,
        "kv_heads": "model" if kv_ok else None,
        "aseq": None if heads_ok else "model",   # context-parallel fallback
        "ff": "model",
        "expert": "model",
        "vocab": "model",
        "kvseq": kvseq,          # decode cache sequence (batch=1 cells: "data")
    }


def install_annotations(cfg: ModelConfig, mesh: Mesh,
                        layout: str = "tp", kvseq: Any = None) -> None:
    from repro.distributed import annotate
    annotate.set_mesh(mesh, logical_rules(cfg, mesh, layout, kvseq))


def opt_state_shardings(cfg: ModelConfig, mesh: Mesh, opt_state: Dict,
                        params: Pytree, layout: str = "tp") -> Dict:
    ps = param_shardings(cfg, mesh, params, layout)
    return {
        "m": ps, "v": ps,
        "step": NamedSharding(mesh, P()),
    }


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                batch: Dict, layout: str = "tp") -> Dict:
    """Input shardings: batch on data axes; batch=1 cells shard sequence."""
    daxes = tuple(mesh.axis_names) if layout == "fsdp" else _data_axes(mesh)
    dax = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    out = {}
    for k, v in batch.items():
        shp = v.shape
        if k == "pos" or v.ndim == 0:
            out[k] = P()
        elif shp[0] == 1 and v.ndim >= 2 and shp[1] > 1:
            # batch=1 (long_500k): shard the sequence axis instead (SP)
            out[k] = P(None, dax, *([None] * (v.ndim - 2)))
        else:
            out[k] = P(dax, *([None] * (v.ndim - 1)))
    return out


def batch_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                    batch: Dict, layout: str = "tp") -> Dict:
    return {k: NamedSharding(mesh, s)
            for k, s in batch_specs(cfg, shape, mesh, batch, layout).items()}


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache: Pytree,
                batch_size: int) -> Pytree:
    """KV/state cache shardings: batch on data axes (or sequence if batch==1),
    kv-heads on model when divisible else head_dim."""
    m = _msize(mesh)
    daxes = _data_axes(mesh)
    dax = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    dsize = 1
    for a in (daxes or ()):
        dsize *= mesh.shape[a]

    def spec_for(path: str, leaf) -> P:
        nd = leaf.ndim
        lead = (None,) if "stack" in path else ()
        n = nd - len(lead)

        def mk(*axes):
            full = lead + tuple(axes) + (None,) * (nd - len(lead) - len(axes))
            return P(*full[:nd])

        if n == 0:
            return P()
        batch_ok = _div(batch_size, max(dsize, 1)) and batch_size >= dsize
        bax = dax if batch_ok else None
        if ("kv/k" in path or "kv/v" in path or "cross_kv" in path) and n == 4:
            # (B, S, Hkv, D)
            if _div(cfg.num_kv_heads, m):
                return mk(bax, None, "model", None)
            if not batch_ok and _div(leaf.shape[len(lead) + 1], max(dsize, 1)):
                return mk(None, dax, None, "model" if _div(cfg.head_dim, m)
                          else None)
            return mk(bax, None, None, "model" if _div(cfg.head_dim, m)
                      else None)
        # SSM states: (B, d_inner, d_state) / (B, H, dk, dv) / (B, di)
        if n >= 2:
            d1 = leaf.shape[len(lead) + 1]
            return mk(bax, "model" if _div(d1, m) else None)
        return mk(bax)

    flat = jax.tree_util.tree_flatten_with_path(cache)[0]
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in flat]
    specs = [spec_for(k, leaf) for k, (path, leaf) in zip(keys, flat)]
    treedef = jax.tree_util.tree_structure(cache)
    return jax.tree_util.tree_unflatten(treedef, specs)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache: Pytree,
                    batch_size: int) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_specs(cfg, mesh, cache, batch_size))
