"""Pipeline parallelism: 1F1B-style microbatch pipeline over a ``pipe`` mesh axis
via shard_map + collective_permute.

Alternative mesh layout for depth-dominated models (e.g. qwen2-vl 80L): layers
split into ``pipe`` contiguous stages; microbatches stream through with
activations handed between stages by collective_permute.  GPipe-schedule
utilisation = M / (M + S - 1) for M microbatches, S stages; the steady-state
collective per hop is (microbatch, seq, d_model) — counted by the roofline's
collective term.

This module implements the generic stage driver (stage_fn is any
params×activation -> activation function), tested on host devices in
tests/test_distributed.py; the full-model wiring hook is ``split_stage_params``.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

Pytree = Any


def pipeline_forward(stage_fn: Callable[[Pytree, jax.Array], jax.Array],
                     stage_params: Pytree, x_microbatches: jax.Array,
                     mesh: Mesh, axis: str = "pipe") -> jax.Array:
    """Run M microbatches through S pipeline stages (GPipe schedule).

    stage_params: pytree whose leaves carry a leading stage axis, sharded on
    ``axis``; x_microbatches: (M, mb, ...) activations entering stage 0.
    Returns the final-stage outputs (M, mb, ...).
    """
    S = mesh.shape[axis]
    M = x_microbatches.shape[0]
    total_ticks = M + S - 1

    def per_stage(params, xs):
        # params: this stage's slice (leading axis 1); xs: full (M, mb, ...)
        params = jax.tree.map(lambda p: p[0], params)
        stage = jax.lax.axis_index(axis)
        fwd_perm = [(i, i + 1) for i in range(S - 1)]

        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            mb_idx = t - stage                    # which microbatch this stage sees
            # stage 0 ingests from xs; others from the permuted buffer
            inject = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, M - 1), keepdims=False)
            cur = jnp.where(stage == 0, inject, buf)
            active = (mb_idx >= 0) & (mb_idx < M)
            y = stage_fn(params, cur)
            y = jnp.where(active, y, cur)
            # last stage writes its result; others pass forward
            outs = jax.lax.cond(
                active & (stage == S - 1),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(mb_idx, 0, M - 1), axis=0),
                lambda o: o, outs)
            buf = jax.lax.ppermute(y, axis, fwd_perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, total_ticks, tick, (buf, outs))
        # results live on the last stage only; psum replicates them (all other
        # stages contributed zeros), satisfying the replicated out_spec
        return jax.lax.psum(outs, axis)

    in_specs = (jax.tree.map(lambda _: P(axis), stage_params), P())
    fn = shard_map(per_stage, mesh=mesh, in_specs=in_specs, out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x_microbatches)


def split_stage_params(key, S: int, init_one: Callable[[Any], Pytree]) -> Pytree:
    """Initialise S stage-sliced param trees stacked on a leading axis."""
    keys = jax.random.split(key, S)
    return jax.vmap(init_one)(keys)


def bubble_fraction(num_microbatches: int, num_stages: int) -> float:
    """GPipe bubble: (S-1) / (M + S - 1)."""
    return (num_stages - 1) / (num_microbatches + num_stages - 1)
