"""repro.distributed subpackage."""
