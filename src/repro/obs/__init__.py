"""Observability: seam telemetry (measured-vs-TME) for the dispatch layer.

``repro.obs.telemetry`` records per-op events at the dispatch seam, the
compensated reductions, the iterative solvers, and the serving engine;
``repro.obs.report`` turns the counters into the measured-vs-TME-predicted
table (``python -m repro.obs.report``).  Controlled by
``REPRO_TELEMETRY=off|counters|trace`` or ``telemetry_scope(...)``.
"""

from repro.obs.telemetry import (  # noqa: F401
    ENV_VAR,
    MODES,
    TRACE_CAP,
    OpEvent,
    cache_snapshot,
    counters_snapshot,
    enabled,
    get_mode,
    op_end,
    op_start,
    probe,
    record_cache,
    record_event,
    reset,
    set_mode,
    snapshot,
    telemetry_scope,
    trace_snapshot,
    tracing,
    write_json,
)
