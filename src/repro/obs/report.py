"""Measured-vs-TME report — the paper's falsifiability instrument, pointed at
this repo's own seam.

Aggregates the telemetry counters (live, or a ``telemetry.write_json``
snapshot) into one row per (kind, route): calls, mean measured μs, mean
TME-predicted μs, and the model-error ratio measured/TME.  On this CPU
container the ratio is expected to be large (the reference chip is the TPU
v5e spec and the pallas route runs the kernel interpreter) — the point is the
*trajectory*: the ratio is recorded on every CI run, so the accelerator lane
can tighten it into a real gate (see ``benchmarks.check_regression
--telemetry``).

CLI::

    python -m repro.obs.report                 # built-in sweep, then report
    python -m repro.obs.report telemetry.json  # report a saved snapshot
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional

from repro.obs import telemetry

COLUMNS = ("kind", "route", "calls", "mean_us", "tme_us", "ratio")


def _counter_list(snap: Optional[Dict[str, Any]] = None) -> List[Dict[str, Any]]:
    if snap is None:
        snap = telemetry.snapshot()
    return snap.get("counters", [])


def table_rows(snap: Optional[Dict[str, Any]] = None) -> List[Dict[str, Any]]:
    """One row per (kind, route), aggregated over shape classes.

    ``ratio`` is total-measured / total-TME-predicted μs (0.0 when the kind
    has no prediction — solver/serving events).  Rows sort by kind, route.
    """
    agg: Dict[tuple, Dict[str, float]] = {}
    for c in _counter_list(snap):
        key = (c["kind"], c["route"])
        a = agg.setdefault(key, {"calls": 0, "us": 0.0, "tme_us": 0.0})
        a["calls"] += int(c["calls"])
        a["us"] += float(c["us"])
        a["tme_us"] += float(c["tme_us"])
    rows = []
    for (kind, route), a in sorted(agg.items()):
        calls = max(a["calls"], 1)
        rows.append({
            "kind": kind, "route": route, "calls": a["calls"],
            "mean_us": a["us"] / calls,
            "tme_us": a["tme_us"] / calls,
            "ratio": a["us"] / a["tme_us"] if a["tme_us"] > 0 else 0.0,
        })
    return rows


def render(rows: List[Dict[str, Any]], chip: str = "") -> str:
    """Fixed-width text table of ``table_rows`` output."""
    head = f"measured vs TME-predicted (chip model: {chip})" if chip else \
        "measured vs TME-predicted"
    lines = [head,
             f"{'kind':<14} {'route':<8} {'calls':>6} {'mean_us':>12} "
             f"{'tme_us':>12} {'ratio':>10}"]
    for r in rows:
        ratio = f"{r['ratio']:.1f}x" if r["ratio"] else "-"
        tme_us = f"{r['tme_us']:.3f}" if r["tme_us"] else "-"
        lines.append(f"{r['kind']:<14} {r['route'] or '-':<8} "
                     f"{r['calls']:>6d} {r['mean_us']:>12.2f} "
                     f"{tme_us:>12} {ratio:>10}")
    return "\n".join(lines)


def _builtin_sweep() -> None:
    """Tiny workload touching every dispatch kind + the reductions, so a bare
    ``python -m repro.obs.report`` demonstrates the instrument end to end."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import compensated, dispatch, ozaki2

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((64, 64)))
    b = jnp.asarray(rng.standard_normal((64, 64)))
    v = jnp.asarray(rng.standard_normal((64, 4)))
    u = jnp.asarray(rng.standard_normal((8, 8, 8)))
    c = jnp.asarray(np.array([6.0, -1, -1, -1, -1, -1, -1]))
    # r = 7 plan: the default-plan interpreted SpMV costs minutes of XLA-CPU
    # compile (ROADMAP); the bounded plan keeps the demo in seconds.
    plan_r7 = ozaki2.make_plan(4, payload_bits=24, margin_bits=4)
    val = jnp.asarray(rng.standard_normal((32, 4)))
    col = jnp.asarray(rng.integers(0, 32, (32, 4)).astype(np.int32))
    x = jnp.asarray(rng.standard_normal(32))
    q = jnp.asarray(rng.standard_normal((16, 8)))
    kq = jnp.asarray(rng.standard_normal((16, 8)))
    vq = jnp.asarray(rng.standard_normal((16, 8)))
    causal = jnp.tril(jnp.ones((16, 16), jnp.int8))
    for mode in ("xla", "pallas"):
        dispatch.matmul(a, b, mode=mode)
        dispatch.matmul(a, v, mode=mode)
        dispatch.stencil7(u, c, bz=4, mode=mode)
        dispatch.spmv(val, col, x, plan=plan_r7, br=8, mode=mode)
        dispatch.attention(q, kq, vq, mask=causal, mode=mode)
    compensated.compensated_dot(jnp.asarray(rng.standard_normal(4096)),
                                jnp.asarray(rng.standard_normal(4096)))


def main(argv=None) -> int:
    """CLI entry: report a saved snapshot, or sweep-and-report (see module)."""
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("snapshot", nargs="?", default=None,
                        help="telemetry snapshot JSON (from "
                             "telemetry.write_json); omitted = run a small "
                             "built-in sweep and report it")
    parser.add_argument("--json", action="store_true",
                        help="emit the table as JSON rows instead of text")
    args = parser.parse_args(argv)

    if args.snapshot is None:
        # Standalone CLI: the emulation kernels assume f64 operands (the
        # benchmark harness and test conftest both enable x64 before jax
        # initialises; this entry point must too).
        import jax

        jax.config.update("jax_enable_x64", True)

    if args.snapshot is not None:
        with open(args.snapshot) as fh:
            snap = json.load(fh)
    else:
        telemetry.reset()
        with telemetry.telemetry_scope("trace"):
            _builtin_sweep()
        snap = telemetry.snapshot()

    rows = table_rows(snap)
    if args.json:
        json.dump(rows, sys.stdout, indent=2)
        print()
    else:
        print(render(rows, chip=snap.get("chip", "")))
    return 0


if __name__ == "__main__":
    sys.exit(main())
