"""Seam telemetry — live measured-vs-TME tracing for the dispatch layer.

The paper's central claim is falsifiable *by instrument*: the TME model
(``repro.core.tme``, eqs. 8–9) predicts emulated-FP64 time from (α, β, γ),
and every emulated multiplication in this repo already routes through one
seam (``repro.core.dispatch``).  This module records what actually happens at
that seam — so "measured vs TME-predicted" is a continuously collected
quantity, not a hand-run benchmark.

What gets recorded per dispatched op (``op_start``/``op_end`` around the
route execution): kind, shape-class, chosen route, the plan's r and
payload_bits, wall time (``jax.block_until_ready``-fenced), the derived
FLOPs/bytes of the FP64-equivalent op, and the TME-predicted time for the
same op on the reference chip (``tme.default_chip``, $REPRO_TME_CHIP).
Plan/tuning cache hits and misses are counted separately (``record_cache``),
and free-form events (solver residual traces, serving step latencies) ride
the same stream via ``record_event``.

Storage is two-tier, selected by ``REPRO_TELEMETRY=off|counters|trace`` (or
the ``telemetry_scope(...)`` context manager / ``set_mode``, mirroring
``dispatch.mode_scope``):

  * **counters** — per-(kind, shape-class, route) aggregates: call count,
    total/min/max wall μs, total FLOPs/bytes, total TME-predicted μs.  O(1)
    memory regardless of run length.
  * **trace** — counters *plus* a bounded ring buffer (``TRACE_CAP`` most
    recent events) for post-hoc inspection; old events fall off the end.

Two invariants the instrumented call-sites rely on:

  * **Tracer-safe** — ``op_start`` returns ``None`` (and ``record_event``
    no-ops) when any operand is a ``jax.core.Tracer``: instrumented entry
    points still jit, and a traced call records nothing (there is no wall
    time to measure inside a trace anyway).  Recording never adds ops to a
    jaxpr.
  * **Zero-overhead when off** — the off path is one thread-local/env lookup
    per call; no timing fence, no allocation, no lock.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax

from repro.core import tme

MODES = ("off", "counters", "trace")
ENV_VAR = "REPRO_TELEMETRY"

# Ring-buffer capacity in trace mode (most recent events win).
TRACE_CAP = 4096

_tls = threading.local()
_lock = threading.Lock()

# (kind, shape_class, route) -> mutable aggregate dict.
_counters: Dict[Tuple[str, str, str], Dict[str, float]] = {}
# cache name ("plan" | "tune") -> [hits, misses]
_caches: Dict[str, List[int]] = {}
_trace: deque = deque(maxlen=TRACE_CAP)


@dataclasses.dataclass(frozen=True)
class OpEvent:
    """One recorded event.  Dispatch ops fill every field; free-form events
    (``record_event``) carry their payload in ``extra`` and may leave the
    plan/cost fields at zero."""
    kind: str
    shape_class: str
    route: str
    r: int
    payload_bits: int
    us: float                  # measured wall time (block_until_ready-fenced)
    flops: float               # W of the FP64-equivalent op
    bytes: float               # Q of the FP64-equivalent op
    tme_us: float              # TME-predicted time for the same op
    label: str = ""
    extra: Tuple[Tuple[str, Any], ...] = ()


# ---------------------------------------------------------------------------
# Mode resolution (mirrors dispatch.mode_scope)
# ---------------------------------------------------------------------------

def _validate_mode(mode: str) -> str:
    if mode not in MODES:
        raise ValueError(f"telemetry mode must be one of {MODES}, got {mode!r}")
    return mode


def get_mode() -> str:
    """Effective telemetry mode: programmatic override, else env, else off."""
    override = getattr(_tls, "mode", None)
    if override is not None:
        return override
    return _validate_mode(os.environ.get(ENV_VAR, "off"))


def set_mode(mode: Optional[str]) -> None:
    """Set (or with None, clear) this thread's telemetry-mode override."""
    _tls.mode = None if mode is None else _validate_mode(mode)


@contextlib.contextmanager
def telemetry_scope(mode: Optional[str]):
    """Temporarily force a telemetry mode (None = inherit the ambient mode)."""
    prev = getattr(_tls, "mode", None)
    set_mode(mode if mode is not None else prev)
    try:
        yield
    finally:
        _tls.mode = prev


def enabled() -> bool:
    """Whether any recording is active.  This is the per-call fast path the
    instrumented seams check first — keep it one lookup, no allocation."""
    mode = getattr(_tls, "mode", None)
    if mode is None:
        mode = os.environ.get(ENV_VAR, "off")
    if mode == "off":
        return False
    _validate_mode(mode)
    return True


def tracing() -> bool:
    """Whether the per-event trace ring is filling (mode == "trace")."""
    return get_mode() == "trace"


# ---------------------------------------------------------------------------
# Recording
# ---------------------------------------------------------------------------

def reset() -> None:
    """Drop all counters, cache tallies, and the trace ring buffer."""
    with _lock:
        _counters.clear()
        _caches.clear()
        _trace.clear()


def _shape_class(dims: Sequence[int]) -> str:
    if not dims:
        return ""
    # Deferred: dispatch imports this module at load time, not vice versa.
    from repro.core.dispatch import shape_class
    return shape_class(dims)


def _record(ev: OpEvent) -> None:
    key = (ev.kind, ev.shape_class, ev.route)
    with _lock:
        agg = _counters.get(key)
        if agg is None:
            agg = _counters[key] = {
                "calls": 0, "us": 0.0, "us_min": float("inf"), "us_max": 0.0,
                "flops": 0.0, "bytes": 0.0, "tme_us": 0.0,
            }
        agg["calls"] += 1
        agg["us"] += ev.us
        agg["us_min"] = min(agg["us_min"], ev.us)
        agg["us_max"] = max(agg["us_max"], ev.us)
        agg["flops"] += ev.flops
        agg["bytes"] += ev.bytes
        agg["tme_us"] += ev.tme_us
        if get_mode() == "trace":
            _trace.append(ev)


def _has_tracer(values) -> bool:
    return any(isinstance(v, jax.core.Tracer) for v in values)


def op_start(kind: str, dims: Sequence[int], route: str, plan=None,
             *operands, label: str = ""):
    """Begin recording one dispatched op; returns an opaque token for
    ``op_end``, or None when recording is off or any operand is a tracer
    (instrumented entry points must stay jit-traceable)."""
    if not enabled():
        return None
    if _has_tracer(operands):
        return None
    return (kind, tuple(int(d) for d in dims), route, plan, label,
            time.perf_counter())


def op_end(token, out):
    """Finish the op begun by ``op_start``: fence with ``block_until_ready``,
    compute derived FLOPs/bytes and the TME prediction, record, and return
    ``out`` (so call-sites can ``return op_end(tok, out)``)."""
    if token is None:
        return out
    if isinstance(out, jax.core.Tracer):  # concrete inputs, traced output
        return out
    kind, dims, route, plan, label, t0 = token
    out = jax.block_until_ready(out)
    us = (time.perf_counter() - t0) * 1e6
    W, Q, n_out = tme.op_costs(kind, dims)
    if plan is not None:
        r, pb = plan.r, plan.payload_bits
        tme_us = tme.predict_op_time(kind, dims, r=r, alpha=float(plan.alpha),
                                     substrate=plan.substrate,
                                     route=route) * 1e6
    else:
        r, pb = 0, 0
        tme_us = tme.predict_op_time(kind, dims, route=route) * 1e6
    _record(OpEvent(kind, _shape_class(dims), route, r, pb, us, W, Q, tme_us,
                    label=label))
    return out


def record_event(kind: str, *, us: float = 0.0, dims: Sequence[int] = (),
                 route: str = "", label: str = "", **extra) -> None:
    """Record a free-form event (solver residuals, serving latencies, queue
    depths).  No TME prediction; tracer-valued payloads are dropped whole."""
    if not enabled():
        return
    if _has_tracer(extra.values()):
        return
    _record(OpEvent(kind, _shape_class(dims), route, 0, 0, float(us),
                    0.0, 0.0, 0.0, label=label,
                    extra=tuple(sorted(extra.items()))))


def record_cache(name: str, hit: bool) -> None:
    """Count a plan/tuning cache lookup (only called when recording is on)."""
    with _lock:
        tally = _caches.setdefault(name, [0, 0])
        tally[0 if hit else 1] += 1


# ---------------------------------------------------------------------------
# Introspection
# ---------------------------------------------------------------------------

def counters_snapshot() -> Dict[Tuple[str, str, str], Dict[str, float]]:
    """Copy of the aggregate counters, keyed (kind, shape_class, route)."""
    with _lock:
        return {k: dict(v) for k, v in _counters.items()}


def cache_snapshot() -> Dict[str, Tuple[int, int]]:
    """Cache tallies: name -> (hits, misses)."""
    with _lock:
        return {k: (v[0], v[1]) for k, v in _caches.items()}


def trace_snapshot() -> List[OpEvent]:
    """Copy of the ring buffer (oldest first; trace mode only fills it)."""
    with _lock:
        return list(_trace)


def snapshot() -> Dict[str, Any]:
    """JSON-serialisable snapshot of everything recorded so far."""
    counters = [
        {"kind": k, "shape_class": cls, "route": route, **agg}
        for (k, cls, route), agg in sorted(counters_snapshot().items())
    ]
    return {
        "mode": get_mode(),
        "chip": tme.default_chip().name,
        "counters": counters,
        "caches": {name: {"hits": h, "misses": m}
                   for name, (h, m) in sorted(cache_snapshot().items())},
        "trace": [dataclasses.asdict(ev) for ev in trace_snapshot()],
    }


def write_json(path: str) -> str:
    """Dump ``snapshot()`` to ``path`` (the CI telemetry artifact)."""
    with open(path, "w") as fh:
        json.dump(snapshot(), fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


def probe(fn):
    """Run ``fn`` once under trace telemetry and return ``(result, event)``
    where ``event`` is the last dispatched-op event it produced (None if it
    recorded none).  Benchmarks use this to source the route/shape-class CSV
    columns from the telemetry stream rather than re-deriving them."""
    with telemetry_scope("trace"):
        before = len(_trace)
        out = jax.block_until_ready(fn())
        new = list(_trace)[before:]
    for ev in reversed(new):
        if ev.route:
            return out, ev
    return out, None
